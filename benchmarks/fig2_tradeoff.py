"""Paper Figure 2: accuracy vs memory-reduction trade-off — Representer
Sketch against iterative pruning and knowledge distillation baselines.

Baselines (as in the paper §4.2):
  * One-/multi-time global magnitude pruning of the trained MLP + finetune.
  * Knowledge distillation into smaller MLPs (Hinton-style, MSE on logits).
Sketch sweeps L (rows) to move along the memory axis.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DistillConfig, KernelModel, KernelModelConfig,
                        distill, mlp_memory_params)
from repro.core.distill import _adam_init, _adam_update
from repro.core.teacher import MLPConfig, init_mlp, mlp_forward, train_mlp
from repro.data.tabular import DATASETS, make_dataset


def _acc(params, x, y):
    return float(jnp.mean(jnp.argmax(mlp_forward(params, x), -1) == y))


def _prune(params, frac: float):
    """Global magnitude pruning: zero the lowest-|w| fraction of weights."""
    flat = jnp.concatenate([p["w"].ravel() for p in params])
    thresh = jnp.quantile(jnp.abs(flat), frac)
    return [{"w": jnp.where(jnp.abs(p["w"]) < thresh, 0.0, p["w"]),
             "b": p["b"]} for p in params]


def _finetune(params, x, y, mask, steps=300, lr=1e-3):
    opt = _adam_init(params)

    def loss_fn(p, xb, yb):
        logp = jax.nn.log_softmax(mlp_forward(p, xb))
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    @jax.jit
    def step(carry, key):
        p, o = carry
        idx = jax.random.randint(key, (256,), 0, x.shape[0])
        _, g = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        g = jax.tree.map(lambda gi, mi: gi * mi, g, mask)  # keep zeros pruned
        p, o = _adam_update(p, g, o, lr, 0.0)
        return (p, o), None

    keys = jax.random.split(jax.random.PRNGKey(0), steps)
    (params, _), _ = jax.lax.scan(step, (params, opt), keys)
    return params


def run(dataset: str = "adult", seed: int = 0) -> List[Dict]:
    spec = DATASETS[dataset]
    xtr, ytr, xte, yte = make_dataset(spec, seed=seed)
    xtr, ytr = jnp.asarray(xtr[:12000]), jnp.asarray(ytr[:12000])
    xte, yte = jnp.asarray(xte[:3000]), jnp.asarray(yte[:3000])

    mlp_cfg = MLPConfig(spec.n_features, spec.nn_hidden, 2)
    teacher, _ = train_mlp(jax.random.PRNGKey(seed), mlp_cfg, xtr, ytr,
                           n_steps=1200)
    base_mem = mlp_memory_params(mlp_cfg.layer_sizes)
    rows = [{"method": "NN", "reduction": 1.0, "acc": _acc(teacher, xte, yte)}]

    # --- pruning curve -------------------------------------------------------
    for frac in (0.5, 0.8, 0.9, 0.95, 0.98, 0.99):
        pruned = _prune(teacher, frac)
        mask = [{"w": (p["w"] != 0).astype(jnp.float32),
                 "b": jnp.ones_like(p["b"])} for p in pruned]
        tuned = _finetune(pruned, xtr, ytr, mask)
        rows.append({"method": "prune", "reduction": 1.0 / (1.0 - frac),
                     "acc": _acc(tuned, xte, yte)})

    # --- KD curve ------------------------------------------------------------
    for hidden in ((64, 32), (24, 12), (8, 4)):
        student_cfg = MLPConfig(spec.n_features, hidden, 2)
        student = init_mlp(jax.random.PRNGKey(seed + 3), student_cfg)
        opt = _adam_init(student)
        targets = mlp_forward(teacher, xtr)

        def loss_fn(p, xb, tb):
            return jnp.mean((mlp_forward(p, xb) - tb) ** 2)

        @jax.jit
        def step(carry, key):
            p, o = carry
            idx = jax.random.randint(key, (256,), 0, xtr.shape[0])
            _, g = jax.value_and_grad(loss_fn)(p, xtr[idx], targets[idx])
            p, o = _adam_update(p, g, o, 1e-3, 0.0)
            return (p, o), None

        keys = jax.random.split(jax.random.PRNGKey(1), 1500)
        (student, _), _ = jax.lax.scan(step, (student, opt), keys)
        red = base_mem / mlp_memory_params(student_cfg.layer_sizes)
        rows.append({"method": "kd", "reduction": red,
                     "acc": _acc(student, xte, yte)})

    # --- Representer Sketch curve --------------------------------------------
    model = KernelModel(KernelModelConfig(
        in_dim=spec.n_features, proj_dim=16, n_points=256, n_outputs=2,
        bandwidth=2.0, k=spec.rs_K))
    kparams, _ = distill(jax.random.PRNGKey(seed + 1),
                         lambda x: mlp_forward(teacher, x), xtr, model,
                         DistillConfig(n_steps=1500, lr=5e-3))
    for n_rows in (2000, 800, 300, 100, 40):
        sk, state = model.freeze(jax.random.PRNGKey(seed + 2), kparams,
                                 n_rows=n_rows, n_buckets=16)
        out = sk.query(state, model.transform(kparams, xte))
        acc = float(jnp.mean(jnp.argmax(out, -1) == yte))
        red = base_mem / model.sketch_memory_params(n_rows, 16)
        rows.append({"method": "sketch", "reduction": red, "acc": acc})

    for r in rows:
        print(f"  {r['method']:7s} reduction {r['reduction']:7.1f}x "
              f"acc {r['acc']:.3f}")
    return rows
