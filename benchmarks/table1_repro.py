"""Paper Table 1 reproduction: accuracy / memory / FLOPs for NN vs Kernel
vs Representer Sketch on the six (synthetic stand-in) tabular tasks.

Protocol per dataset (paper §3.4/§4):
  1. Train the Table-2 MLP teacher.
  2. Distill into the weighted LSH-kernel model (M ≪ N anchors, asymmetric
     projection A, MSE on teacher outputs).
  3. Freeze into a Representer Sketch (Table-2 R, K; L set by the error
     budget) and evaluate with hash+gather+MoM only.
Memory counts parameters (sketch: C·L·R + d·d' proj, paper §4.3); FLOPs use
the paper's inference model (2·d·p + p·K·L/3 + L·C vs dense MACs).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DistillConfig, KernelModel, KernelModelConfig,
                        distill, mlp_flops, mlp_memory_params)
from repro.core.teacher import MLPConfig, mlp_forward, train_mlp
from repro.data.tabular import DATASETS, TabularSpec, make_dataset

# Fast-mode budget so `python -m benchmarks.run` completes on one CPU core;
# paper-scale settings are the spec defaults (scaled by --full in run.py).
FAST = {"nn_steps": 1200, "distill_steps": 1500, "n_points": 256,
        "rows": 1200, "train_cap": 12000, "test_cap": 3000}


def _metric(task, out, y):
    if task == "classification":
        return float(jnp.mean(jnp.argmax(out, -1) == y))
    return float(jnp.mean(jnp.abs(out[:, 0] - y)))


def run_dataset(name: str, budget: Dict = FAST, seed: int = 0) -> Dict:
    spec = DATASETS[name]
    xtr, ytr, xte, yte = make_dataset(spec, seed=seed)
    xtr, ytr = xtr[: budget["train_cap"]], ytr[: budget["train_cap"]]
    xte, yte = xte[: budget["test_cap"]], yte[: budget["test_cap"]]
    xtr_j, xte_j = jnp.asarray(xtr), jnp.asarray(xte)
    ytr_j, yte_j = jnp.asarray(ytr), jnp.asarray(yte)
    n_out = 2 if spec.task == "classification" else 1

    t0 = time.time()
    mlp_cfg = MLPConfig(spec.n_features, spec.nn_hidden, n_out)
    teacher, _ = train_mlp(jax.random.PRNGKey(seed), mlp_cfg, xtr_j, ytr_j,
                           task=spec.task, n_steps=budget["nn_steps"])
    nn_metric = _metric(spec.task, mlp_forward(teacher, xte_j), yte_j)

    proj_dim = min(max(spec.n_features // 2, 4), 32)
    model = KernelModel(KernelModelConfig(
        in_dim=spec.n_features, proj_dim=proj_dim,
        n_points=budget["n_points"], n_outputs=n_out, bandwidth=2.0,
        k=spec.rs_K))
    # Regression is precision-hungry: the sketch's collision-noise floor
    # (Σ|α|/√R) must sit below the target MAE, so regression tasks get an
    # L1-regularized distillation and a wider array (see EXPERIMENTS.md).
    regression = spec.task == "regression"
    kparams, _ = distill(
        jax.random.PRNGKey(seed + 1), lambda x: mlp_forward(teacher, x),
        xtr_j, model, DistillConfig(n_steps=budget["distill_steps"], lr=5e-3,
                                    alpha_l1=1e-3 if regression else 0.0))
    kernel_metric = _metric(spec.task, model.apply(kparams, xte_j), yte_j)

    n_buckets = 64 if regression else max(spec.rs_R // 10, 16)
    sk, state = model.freeze(jax.random.PRNGKey(seed + 2), kparams,
                             n_rows=budget["rows"] * (2 if regression else 1),
                             n_buckets=n_buckets)
    rs_out = sk.query(state, model.transform(kparams, xte_j))
    rs_metric = _metric(spec.task, rs_out, yte_j)

    nn_mem = mlp_memory_params(mlp_cfg.layer_sizes) * 8 / 1e6   # 64-bit, MB
    rs_mem = (model.sketch_memory_params(budget["rows"], n_buckets)
              * 8 / 1e6)
    nn_fl = mlp_flops(mlp_cfg.layer_sizes)
    rs_fl = model.sketch_flops(budget["rows"], n_buckets)

    return {
        "dataset": name, "task": spec.task,
        "nn": nn_metric, "kernel": kernel_metric, "rs": rs_metric,
        "nn_mem_mb": nn_mem, "rs_mem_mb": rs_mem,
        "mem_reduction": nn_mem / rs_mem,
        "nn_flops": nn_fl, "rs_flops": rs_fl,
        "flop_reduction": nn_fl / rs_fl,
        "seconds": time.time() - t0,
    }


def run(budget: Dict = FAST):
    rows = []
    for name in DATASETS:
        r = run_dataset(name, budget)
        rows.append(r)
        print(f"  {r['dataset']:9s} {r['task'][:5]:5s} "
              f"NN={r['nn']:.3f} K={r['kernel']:.3f} RS={r['rs']:.3f}  "
              f"mem {r['mem_reduction']:6.1f}x  flops "
              f"{r['flop_reduction']:6.1f}x  ({r['seconds']:.0f}s)")
    return rows
