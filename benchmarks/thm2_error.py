"""Theorem 2 validation: measured MoM estimation error vs the analytic
6·σ̃/√L·√log(1/δ) bound, swept over L."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RepresenterSketch, SketchConfig


def run(delta: float = 0.05):
    dim, m = 6, 400
    kp, kd, kq = jax.random.split(jax.random.PRNGKey(0), 3)
    pts = jax.random.normal(kd, (m, dim))
    alphas = jax.random.normal(kp, (m, 1))
    queries = jax.random.normal(kq, (200, dim))
    rows = []
    for l in (50, 100, 200, 400, 800, 1600):
        cfg = SketchConfig(n_rows=l, n_buckets=16, k=1, dim=dim,
                           n_outputs=1, bandwidth=2.0, n_groups=8)
        sk = RepresenterSketch(cfg)
        state = sk.build(sk.init(jax.random.PRNGKey(l)), pts, alphas)
        est = sk.query(state, queries)
        exact = sk.exact_weighted_kde(pts, alphas, queries)
        dist = jnp.linalg.norm(queries[:, None] - pts[None], axis=-1)
        sigma = jnp.sqrt(sk.lsh.collision_probability(dist)) @ jnp.abs(alphas)
        bound = 6.0 * sigma / np.sqrt(l) * np.sqrt(np.log(1 / delta))
        err = np.abs(np.asarray(est - exact))
        q95 = float(np.quantile(err, 1 - delta))
        rows.append({"L": l, "mean_err": float(err.mean()),
                     "q95_err": q95,
                     "bound_mean": float(np.asarray(bound).mean()),
                     "within_bound": float(np.mean(err <= np.asarray(bound)))})
        print(f"  L={l:5d} mean|err|={rows[-1]['mean_err']:.4f} "
              f"q95={q95:.4f} bound≈{rows[-1]['bound_mean']:.4f} "
              f"P[err≤bound]={rows[-1]['within_bound']:.3f}")
    return rows
