"""Benchmark harness — one section per paper table/figure + framework perf.

  PYTHONPATH=src python -m benchmarks.run [--fast|--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines at the end for machine
consumption, with human-readable sections above.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table1,fig2,thm2,sketch_head,engine,"
                         "kernels,roofline")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (slower)")
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "two_kernel", "ref"],
                    help="sketch-head decode backend for the serving "
                         "benchmarks (recorded in the BENCH_*.json head "
                         "metadata; DESIGN.md §8)")
    ap.add_argument("--mesh", default=None,
                    help="'<data>x<model>' serving mesh for the serving "
                         "benchmarks (e.g. 4x2; needs XLA_FLAGS forced "
                         "devices on CPU).  Recorded in every BENCH_*.json "
                         "record's mesh field; default single-device 1x1")
    ap.add_argument("--quant", default=None, choices=["int8", "int4"],
                    help="serve the sketch-head benchmark from quantized "
                         "count-array storage (DESIGN.md §12)")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    csv_rows = []

    def want(name):
        return not only or name in only

    if want("table1"):
        print("== Table 1: accuracy / memory / FLOPs (NN vs Kernel vs RS) ==")
        from benchmarks import table1_repro
        budget = dict(table1_repro.FAST)
        if args.full:
            budget.update(nn_steps=4000, distill_steps=5000, n_points=512,
                          rows=2000, train_cap=10**9, test_cap=10**9)
        t0 = time.time()
        rows = table1_repro.run(budget)
        for r in rows:
            csv_rows.append((f"table1/{r['dataset']}",
                             r["seconds"] * 1e6,
                             f"mem_red={r['mem_reduction']:.1f}x;"
                             f"flop_red={r['flop_reduction']:.1f}x;"
                             f"nn={r['nn']:.3f};rs={r['rs']:.3f}"))
        print(f"  [table1 total {time.time() - t0:.0f}s]\n")

    if want("fig2"):
        print("== Figure 2: accuracy vs memory reduction (vs prune/KD) ==")
        from benchmarks import fig2_tradeoff
        rows = fig2_tradeoff.run("adult")
        for r in rows:
            csv_rows.append((f"fig2/{r['method']}@{r['reduction']:.0f}x",
                             0.0, f"acc={r['acc']:.3f}"))
        print()

    if want("thm2"):
        print("== Theorem 2: MoM error vs bound, swept over L ==")
        from benchmarks import thm2_error
        rows = thm2_error.run()
        for r in rows:
            csv_rows.append((f"thm2/L{r['L']}", 0.0,
                             f"err={r['mean_err']:.4f};"
                             f"cover={r['within_bound']:.3f}"))
        print()

    if want("sketch_head"):
        print("== Sketched LM head vs dense head ==")
        from benchmarks import sketch_head_bench
        r = sketch_head_bench.run(backend=args.backend, mesh=args.mesh,
                                  quant=args.quant)
        csv_rows.append(("sketch_head/dense", r["us_dense"],
                         f"flops={r['dense_flops']}"))
        csv_rows.append((f"sketch_head/{r['head']['backend']}",
                         r["us_sketch"],
                         f"flops={r['sketch_flops']};"
                         f"flop_ratio={r['flop_ratio']:.1f}x;"
                         f"bytes_ratio={r['bytes_ratio']:.2f}x"))
        for mode, e in r["quant_curve"].items():
            csv_rows.append((f"sketch_head/quant_{mode}", 0.0,
                             f"logit_mae={e['logit_mae']:.4f};"
                             f"top1={e['top1_agreement']:.3f};"
                             f"bytes_ratio={e['bytes_ratio']:.2f}x"))
        print()

    if want("engine"):
        print("== Continuous-batching engine vs static batching ==")
        from benchmarks import engine_bench
        r = engine_bench.run(backend=args.backend, mesh=args.mesh)
        csv_rows.append(("engine/static", 0.0,
                         f"tok_s={r['static']['tok_s']:.1f};"
                         f"util={r['static']['slot_utilization']:.2f}"))
        csv_rows.append(("engine/continuous", 0.0,
                         f"tok_s={r['engine']['tok_s']:.1f};"
                         f"util={r['engine']['slot_utilization']:.2f};"
                         f"speedup={r['tok_s_speedup']:.2f}x"))
        for k, m in r["megastep"].items():
            csv_rows.append((f"engine/megastep_k{k}", 0.0,
                             f"tok_s={m['tok_s']:.1f};"
                             f"dispatches={m['megasteps']};"
                             f"host_syncs_per_tok="
                             f"{m['host_syncs_per_token']:.2f}"))
        ht = r["heavy_tail"]
        for mode in ("contiguous", "paged"):
            m = ht[mode]
            csv_rows.append((f"engine/heavy_tail_{mode}", 0.0,
                             f"tok_s={m['tok_s']:.1f};"
                             f"tok_s_slot={m['tokens_per_s_per_slot']:.1f};"
                             f"p50={m['latency_ticks_p50']:.0f};"
                             f"p99={m['latency_ticks_p99']:.0f};"
                             f"prefills={m['prefill_batches']}"))
        csv_rows.append(("engine/heavy_tail_paging", 0.0,
                         f"hit_rate={ht['prefix_hit_rate']:.2f};"
                         f"pages_peak={ht['pages_in_use_peak']};"
                         f"outputs_match={ht['outputs_match']}"))
        print()

    if want("kernels"):
        print("== Kernel micro-benchmarks (cpu reference paths) ==")
        from benchmarks import kernels_bench
        rows = kernels_bench.run()
        for name, us in rows.items():
            csv_rows.append((f"kernels/{name}", us, ""))
        print()

    if want("roofline"):
        print("== Roofline (from dry-run artifacts, if present) ==")
        from benchmarks import roofline
        rows = roofline.run("single")
        for r in rows:
            csv_rows.append(
                (f"roofline/{r['arch']}/{r['shape']}",
                 r["step_lower_bound_s"] * 1e6,
                 f"bottleneck={r['bottleneck']};"
                 f"roofline={100 * r['roofline_fraction']:.1f}%"))
        print()

    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
