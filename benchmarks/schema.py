"""Shared schema for the BENCH_*.json records (EXPERIMENTS.md §Bench schema).

Every serving benchmark record carries

* ``schema_version`` — bumped whenever a field is added/renamed,
* ``mesh`` — the device mesh the numbers were measured on (``1x1`` for the
  default single-device run), and
* (v3) ``decode_chunk`` — the decode megastep size K the record's serving
  loop ran at (launch/decode_loop.py, DESIGN.md §10),

so downstream consumers (README results table, dashboards, the CI
bench-smoke job) can tell a single-device artifact from a sharded one and a
host-loop run from a megastep run without guessing from file mtimes.
Version history:

  1 (implicit) — head {kind, backend} only, no version field
  2            — adds schema_version + mesh {spec, data, model, devices}
  3            — adds decode_chunk; engine run records gain
                 ``host_syncs_per_token`` and ``megasteps`` (device
                 dispatches), and BENCH_engine.json gains the ``megastep``
                 sweep: {str(K): engine run record} for K ∈ the swept
                 chunk sizes

``validate_engine_record`` / ``validate_serve_record`` are the structural
checks the CI bench-smoke job runs on freshly emitted artifacts:

  PYTHONPATH=src python -m benchmarks.schema BENCH_engine.json
"""

from __future__ import annotations

SCHEMA_VERSION = 3

#: Fields every timed serving-run record must carry (schema v3).
_RUN_FIELDS = ("seconds", "tokens", "tok_s", "decode_steps")
_ENGINE_RUN_FIELDS = _RUN_FIELDS + ("megasteps", "host_syncs_per_token")


def mesh_record(mesh=None) -> dict:
    """The ``mesh`` field for a BENCH record (single-device when None)."""
    if mesh is None:
        return {"spec": "1x1", "data": 1, "model": 1, "devices": 1}
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d, m = axes.get("data", 1), axes.get("model", 1)
    return {"spec": f"{d}x{m}", "data": d, "model": m,
            "devices": int(mesh.devices.size)}


def _require(record: dict, fields, where: str) -> None:
    missing = [f for f in fields if f not in record]
    if missing:
        raise ValueError(f"{where}: missing fields {missing}")


def _validate_common(record: dict, name: str) -> None:
    _require(record, ("schema_version", "mesh", "head"), name)
    if record["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"{name}: schema_version {record['schema_version']} != "
            f"{SCHEMA_VERSION} (regenerate with benchmarks/run.py)")
    _require(record["mesh"], ("spec", "data", "model", "devices"),
             f"{name}.mesh")
    _require(record["head"], ("kind", "backend"), f"{name}.head")


def validate_engine_record(record: dict) -> None:
    """Structural check for a BENCH_engine.json record (schema v3).

    Raises ``ValueError`` naming the first missing/mismatched field; used
    by the CI bench-smoke job on freshly emitted artifacts.
    """
    name = "BENCH_engine"
    _validate_common(record, name)
    _require(record, ("decode_chunk", "static", "engine", "megastep"), name)
    _require(record["static"], _RUN_FIELDS, f"{name}.static")
    _require(record["engine"], _ENGINE_RUN_FIELDS, f"{name}.engine")
    if not record["megastep"]:
        raise ValueError(f"{name}.megastep: empty sweep")
    for k, run in record["megastep"].items():
        if int(k) < 1:
            raise ValueError(f"{name}.megastep[{k}]: bad chunk size")
        _require(run, _ENGINE_RUN_FIELDS + ("decode_chunk",),
                 f"{name}.megastep[{k}]")
        if run["decode_chunk"] != int(k):
            raise ValueError(f"{name}.megastep[{k}]: decode_chunk "
                             f"{run['decode_chunk']} != key {k}")


def validate_serve_record(record: dict) -> None:
    """Structural check for a BENCH_sketch_serve.json record (schema v3)."""
    _validate_common(record, "BENCH_sketch_serve")
    _require(record, ("decode_chunk", "us_dense", "us_sketch"),
             "BENCH_sketch_serve")


def main(argv=None) -> None:
    import argparse
    import json
    from pathlib import Path

    ap = argparse.ArgumentParser(
        description="validate BENCH_*.json artifacts against schema "
                    f"v{SCHEMA_VERSION}")
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args(argv)
    for path in args.paths:
        record = json.loads(Path(path).read_text())
        if "megastep" in record or "engine" in record:
            validate_engine_record(record)
        else:
            validate_serve_record(record)
        print(f"{path}: valid (schema v{record['schema_version']})")


if __name__ == "__main__":
    main()
