"""Shared schema for the BENCH_*.json records (EXPERIMENTS.md §Bench schema).

Every serving benchmark record carries

* ``schema_version`` — bumped whenever a field is added/renamed,
* ``mesh`` — the device mesh the numbers were measured on (``1x1`` for the
  default single-device run), and
* (v3) ``decode_chunk`` — the decode megastep size K the record's serving
  loop ran at (launch/decode_loop.py, DESIGN.md §10),

so downstream consumers (README results table, dashboards, the CI
bench-smoke job) can tell a single-device artifact from a sharded one and a
host-loop run from a megastep run without guessing from file mtimes.
Version history:

  1 (implicit) — head {kind, backend} only, no version field
  2            — adds schema_version + mesh {spec, data, model, devices}
  3            — adds decode_chunk; engine run records gain
                 ``host_syncs_per_token`` and ``megasteps`` (device
                 dispatches), and BENCH_engine.json gains the ``megastep``
                 sweep: {str(K): engine run record} for K ∈ the swept
                 chunk sizes
  4            — speculative self-decode (DESIGN.md §11):
                 BENCH_engine.json gains the ``spec_decode`` sweep
                 {str(K): spec run record} and a ``dense_megastep``
                 baseline sweep at the same Ks; spec run records carry
                 ``acceptance_rate`` and ``accepted_tokens_per_verify``;
                 BENCH_sketch_serve.json gains a ``spec_decode`` section
                 with the same two fields
  5            — quantized count-array storage (DESIGN.md §12):
                 BENCH_sketch_serve.json gains the ``quant_curve``
                 accuracy-vs-bits section ({f32, int8, int4}, each with
                 ``logit_mae`` / ``top1_agreement`` / ``bytes_ratio``) and
                 the dtype-aware ``dense_bytes`` / ``sketch_bytes`` /
                 ``bytes_ratio`` cost fields; head records may carry
                 ``quant`` (null / "int8" / "int4")
  6            — paged decode-cache pool + prefix caching (DESIGN.md §13):
                 BENCH_engine.json gains the ``heavy_tail`` section — a
                 Zipf-reuse / bursty-arrival trace served by the contiguous
                 AND the paged engine, with p50/p99 latency (ticks and
                 seconds), ``tokens_per_s_per_slot``, ``prefix_hit_rate``,
                 ``pages_in_use_peak``, ``prefill_batches`` (paged) vs
                 ``prefill_batches_contiguous``, and ``outputs_match``
                 (bitwise parity of the two engines' token streams);
                 BENCH_sketch_serve.json is unchanged structurally
  7            — per-tenant serving (DESIGN.md §14): BENCH_engine.json
                 gains the ``tenants`` section — a Zipf tenant mix over a
                 heavy-tail trace served through an LRU ``HeadCache``
                 smaller than the tenant population, with ``n_tenants``,
                 ``capacity``, the head-cache counters (``hits`` /
                 ``misses`` / ``loads`` / ``evictions``), ``hit_rate``,
                 and the run timing fields; BENCH_sketch_serve.json is
                 unchanged structurally

``validate_engine_record`` / ``validate_serve_record`` are the structural
checks the CI bench-smoke job runs on freshly emitted artifacts.  The CLI
validates *every* path before exiting and reports all failures (exit 1 on
any):

  PYTHONPATH=src python -m benchmarks.schema BENCH_engine.json \
      BENCH_sketch_serve.json
"""

from __future__ import annotations

SCHEMA_VERSION = 7

#: Count-array storage modes of the serve record's ``quant_curve`` (v5).
_QUANT_CURVE_MODES = ("f32", "int8", "int4")
_QUANT_CURVE_FIELDS = ("logit_mae", "top1_agreement", "bytes_ratio")

#: Fields every timed serving-run record must carry (schema v3+).
_RUN_FIELDS = ("seconds", "tokens", "tok_s", "decode_steps")
_ENGINE_RUN_FIELDS = _RUN_FIELDS + ("megasteps", "host_syncs_per_token")
#: Extra fields a speculative-decode run record must carry (schema v4).
_SPEC_RUN_FIELDS = _ENGINE_RUN_FIELDS + (
    "spec_decode", "acceptance_rate", "accepted_tokens_per_verify")
#: Fields the heavy-tail section must carry (schema v6) — the latency
#: percentiles, the serving-density number, and the paging counters.
_HEAVY_TAIL_FIELDS = (
    "requests", "page_size", "contiguous", "paged", "outputs_match",
    "prefix_hit_rate", "pages_in_use_peak", "prefill_batches",
    "prefill_batches_contiguous", "tok_s", "tokens_per_s_per_slot",
    "latency_ticks_p50", "latency_ticks_p99", "latency_s_p50",
    "latency_s_p99")
#: Fields the per-tenant section must carry (schema v7) — the tenant
#: population, the LRU head-cache geometry/counters, and run timing.
_TENANTS_FIELDS = (
    "requests", "n_tenants", "capacity", "hits", "misses", "loads",
    "evictions", "hit_rate", "seconds", "tokens", "tok_s")


def mesh_record(mesh=None) -> dict:
    """The ``mesh`` field for a BENCH record (single-device when None)."""
    if mesh is None:
        return {"spec": "1x1", "data": 1, "model": 1, "devices": 1}
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d, m = axes.get("data", 1), axes.get("model", 1)
    return {"spec": f"{d}x{m}", "data": d, "model": m,
            "devices": int(mesh.devices.size)}


def _require(record: dict, fields, where: str) -> None:
    missing = [f for f in fields if f not in record]
    if missing:
        raise ValueError(f"{where}: missing fields {missing}")


def _validate_common(record: dict, name: str) -> None:
    _require(record, ("schema_version", "mesh", "head"), name)
    if record["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"{name}: schema_version {record['schema_version']} != "
            f"{SCHEMA_VERSION} (regenerate with benchmarks/run.py)")
    _require(record["mesh"], ("spec", "data", "model", "devices"),
             f"{name}.mesh")
    _require(record["head"], ("kind", "backend"), f"{name}.head")


def _validate_spec_run(run: dict, where: str) -> None:
    """One speculative-decode run record (schema v4)."""
    _require(run, _SPEC_RUN_FIELDS, where)
    if not 0.0 <= run["acceptance_rate"] <= 1.0:
        raise ValueError(f"{where}: acceptance_rate "
                         f"{run['acceptance_rate']} outside [0, 1]")
    if run["accepted_tokens_per_verify"] < 0:
        raise ValueError(f"{where}: negative accepted_tokens_per_verify")


def validate_engine_record(record: dict) -> None:
    """Structural check for a BENCH_engine.json record (schema v7).

    Raises ``ValueError`` naming the first missing/mismatched field; used
    by the CI bench-smoke and paged-smoke jobs on freshly emitted
    artifacts.
    """
    name = "BENCH_engine"
    _validate_common(record, name)
    _require(record, ("decode_chunk", "static", "engine", "megastep",
                      "spec_decode", "dense_megastep", "heavy_tail",
                      "tenants"), name)
    _require(record["static"], _RUN_FIELDS, f"{name}.static")
    _require(record["engine"], _ENGINE_RUN_FIELDS, f"{name}.engine")
    ht = record["heavy_tail"]
    _require(ht, _HEAVY_TAIL_FIELDS, f"{name}.heavy_tail")
    if not 0.0 <= ht["prefix_hit_rate"] <= 1.0:
        raise ValueError(f"{name}.heavy_tail: prefix_hit_rate "
                         f"{ht['prefix_hit_rate']} outside [0, 1]")
    if ht["outputs_match"] is not True:
        raise ValueError(f"{name}.heavy_tail: outputs_match is not true — "
                         f"the paged engine diverged from the contiguous "
                         f"engine")
    if ht["prefill_batches"] > ht["prefill_batches_contiguous"]:
        raise ValueError(f"{name}.heavy_tail: paged prefill_batches "
                         f"{ht['prefill_batches']} exceeds contiguous "
                         f"{ht['prefill_batches_contiguous']}")
    if ht["latency_ticks_p99"] < ht["latency_ticks_p50"]:
        raise ValueError(f"{name}.heavy_tail: p99 latency below p50")
    tn = record["tenants"]
    _require(tn, _TENANTS_FIELDS, f"{name}.tenants")
    if tn["n_tenants"] < 1 or tn["capacity"] < 1:
        raise ValueError(f"{name}.tenants: n_tenants {tn['n_tenants']} / "
                         f"capacity {tn['capacity']} below 1")
    if not 0.0 <= tn["hit_rate"] <= 1.0:
        raise ValueError(f"{name}.tenants: hit_rate {tn['hit_rate']} "
                         f"outside [0, 1]")
    if tn["loads"] != tn["misses"]:
        # Every HeadCache miss triggers exactly one loader call.
        raise ValueError(f"{name}.tenants: loads {tn['loads']} != "
                         f"misses {tn['misses']}")
    if not record["megastep"]:
        raise ValueError(f"{name}.megastep: empty sweep")
    for k, run in record["megastep"].items():
        if int(k) < 1:
            raise ValueError(f"{name}.megastep[{k}]: bad chunk size")
        _require(run, _ENGINE_RUN_FIELDS + ("decode_chunk",),
                 f"{name}.megastep[{k}]")
        if run["decode_chunk"] != int(k):
            raise ValueError(f"{name}.megastep[{k}]: decode_chunk "
                             f"{run['decode_chunk']} != key {k}")
    if not record["spec_decode"]:
        raise ValueError(f"{name}.spec_decode: empty sweep")
    for k, run in record["spec_decode"].items():
        if int(k) < 1:
            raise ValueError(f"{name}.spec_decode[{k}]: bad draft length")
        _validate_spec_run(run, f"{name}.spec_decode[{k}]")
        if run["spec_decode"] != int(k):
            raise ValueError(f"{name}.spec_decode[{k}]: spec_decode "
                             f"{run['spec_decode']} != key {k}")
    for k, run in record["dense_megastep"].items():
        _require(run, _ENGINE_RUN_FIELDS + ("decode_chunk",),
                 f"{name}.dense_megastep[{k}]")


def validate_serve_record(record: dict) -> None:
    """Structural check for a BENCH_sketch_serve.json record (schema v7;
    serve records are structurally unchanged since v5)."""
    name = "BENCH_sketch_serve"
    _validate_common(record, name)
    _require(record, ("decode_chunk", "us_dense", "us_sketch",
                      "spec_decode", "quant_curve",
                      "dense_bytes", "sketch_bytes", "bytes_ratio"), name)
    spec = record["spec_decode"]
    _require(spec, ("k", "acceptance_rate", "accepted_tokens_per_verify"),
             f"{name}.spec_decode")
    if not 0.0 <= spec["acceptance_rate"] <= 1.0:
        raise ValueError(f"{name}.spec_decode: acceptance_rate "
                         f"{spec['acceptance_rate']} outside [0, 1]")
    curve = record["quant_curve"]
    _require(curve, _QUANT_CURVE_MODES, f"{name}.quant_curve")
    for mode in _QUANT_CURVE_MODES:
        entry = curve[mode]
        _require(entry, _QUANT_CURVE_FIELDS, f"{name}.quant_curve[{mode}]")
        if not 0.0 <= entry["top1_agreement"] <= 1.0:
            raise ValueError(f"{name}.quant_curve[{mode}]: top1_agreement "
                             f"{entry['top1_agreement']} outside [0, 1]")
        if entry["bytes_ratio"] <= 0:
            raise ValueError(f"{name}.quant_curve[{mode}]: non-positive "
                             f"bytes_ratio {entry['bytes_ratio']}")


def main(argv=None) -> int:
    """Validate every path, report all failures, exit non-zero on any.

    Unlike a plain loop that lets the first ``ValueError`` propagate (which
    would skip the remaining files), every artifact is checked and every
    failure printed before the exit code is decided — CI gets the full
    damage report in one run.
    """
    import argparse
    import json
    from pathlib import Path

    ap = argparse.ArgumentParser(
        description="validate BENCH_*.json artifacts against schema "
                    f"v{SCHEMA_VERSION}")
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args(argv)
    failures = 0
    for path in args.paths:
        try:
            record = json.loads(Path(path).read_text())
            if "megastep" in record or "engine" in record:
                validate_engine_record(record)
            else:
                validate_serve_record(record)
        except (ValueError, KeyError, OSError,
                json.JSONDecodeError) as exc:
            print(f"{path}: INVALID — {exc}")
            failures += 1
        else:
            print(f"{path}: valid (schema v{record['schema_version']})")
    if failures:
        print(f"{failures} of {len(args.paths)} artifacts failed "
              f"schema v{SCHEMA_VERSION} validation")
    return 1 if failures else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
