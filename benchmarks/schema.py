"""Shared schema for the BENCH_*.json records (EXPERIMENTS.md §Bench schema).

Every serving benchmark record carries

* ``schema_version`` — bumped whenever a field is added/renamed, and
* ``mesh`` — the device mesh the numbers were measured on (``1x1`` for the
  default single-device run),

so downstream consumers (README results table, dashboards) can tell a
single-device artifact from a sharded one without guessing from file
mtimes.  Version history:

  1 (implicit) — head {kind, backend} only, no version field
  2            — adds schema_version + mesh {spec, data, model, devices}
"""

from __future__ import annotations

SCHEMA_VERSION = 2


def mesh_record(mesh=None) -> dict:
    """The ``mesh`` field for a BENCH record (single-device when None)."""
    if mesh is None:
        return {"spec": "1x1", "data": 1, "model": 1, "devices": 1}
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d, m = axes.get("data", 1), axes.get("model", 1)
    return {"spec": f"{d}x{m}", "data": d, "model": m,
            "devices": int(mesh.devices.size)}
