"""Static batching vs the continuous-batching engine on a heavy-tail trace.

Every request stream here comes from one generator, ``_heavy_tail_trace``:
a small base-prompt set reused with Zipf weights (production prompt
traffic — a few hot system prompts, a long tail of cold ones), power-law
skewed generation lengths, and bursty Poisson arrivals.  The benchmark
serves it three ways —

  static      FIFO chunks of ``n_slots`` through ``generate()``: every
              chunk decodes until its *slowest* member finishes, finished
              requests pad the batch (the pre-engine serving model)
  engine      repro.launch.engine: retire-on-finish, slots recycled
              mid-decode from the queue
  heavy_tail  the full-scale trace (1k+ requests, variable prompt lengths,
              arrivals honored) through the contiguous AND the paged engine
              (launch/paging.py, DESIGN.md §13): same bitwise outputs,
              p50/p99 latency, tokens/s/slot, prefix-cache hit rate and
              pages-in-use reported side by side

— then sweeps the engine's decode megastep size (``decode_chunk`` ∈
``--chunks``; launch/decode_loop.py, DESIGN.md §10) over the same stream,
then the speculative self-decode draft length (``--spec-decode`` Ks;
DESIGN.md §11) with a *distilled* sketch head drafting and the dense head
verifying — against a ``dense_megastep`` baseline (DenseHead,
``decode_chunk=K``) at the same Ks — then a per-tenant serving section
(``--tenants`` tenants, Zipf-weighted, paged through an LRU ``HeadCache``
smaller than the tenant population; DESIGN.md §14) — and emits
``BENCH_engine.json`` (schema v7: the ``heavy_tail`` section carries the
p50/p99 latency and paging fields, the ``tenants`` section the head-cache
hit/miss/load/eviction counters) at the repo root.  The static/engine/megastep/spec sweeps
pin the trace's prompt length (static batching must stack prompts) and
ignore arrivals (throughput protocol); the heavy_tail section is the
latency protocol.  Decode uses the fused sketch head (the serving hot
path; the relative static/engine numbers are head-agnostic since both
modes share ``serve_step``).  The spec sweep distills its head in-process
(a random head accepts ~1/V of drafts, measuring nothing); the other rows
keep the cheap random head — they never sample from its logits' argmax
quality, only its cost.  Both modes are warmed up first so the timed runs
measure steady-state steps, not compile; the jitted steps are shared via
``jitted_serve_fns`` so they dispatch the same executables.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head
from repro.launch.engine import make_engine
from repro.launch.serve import generate
from repro.models.model import init_model

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _make_head(cfg, backend: str = "fused") -> SketchHead:
    head_cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                                bandwidth=2.0)
    key = jax.random.PRNGKey(0)
    kparams = {
        "points": jax.random.normal(key, (128, head_cfg.proj_dim)),
        "alphas": jax.random.normal(key, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(key, (cfg.d_model, head_cfg.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    return SketchHead(cfg=head_cfg, backend=backend,
                      params=freeze_head(key, kparams, head_cfg))


#: Draft head for the spec sweep — capacity chosen for *acceptance*, not
#: cost: the frozen RACE estimate's row-wise Monte-Carlo variance (~1/L)
#: is what bounds argmax agreement with the dense head, so the spec rows
#: spend rows freely (at smoke scale L > d_model, i.e. the head is *not*
#: cheaper than dense — the record's note says so; the §11 wall-clock win
#: needs the paper-scale L ≪ d regime).
_SPEC_HEAD_CFG = SketchHeadConfig(n_rows=512, n_buckets=32, k=1,
                                  proj_dim=64, bandwidth=2.0)


def _distill_spec_head(params, cfg, reqs, gen_long, backend,
                       distill_steps=300):
    """Distill a draft head on hiddens from the bench stream itself.

    Runs the dense greedy decode over the benchmark prompts once, then one
    ``forward(return_hidden=True)`` pass over the emitted sequences — every
    (prompt + generated) position's final hidden becomes a distillation
    sample.  This is the serving-distribution protocol: random-gaussian
    hiddens probe the whole of R^d where kernel regression cannot
    generalize; the stream's hiddens are the manifold the draft actually
    runs on (argmax agreement ~0.15 random vs ~0.5+ stream at the smoke
    scale, 2k distill steps).
    """
    from repro.core.distill import DistillConfig
    from repro.core.sketch_lm_head import distill_head
    from repro.models.model import forward

    head_cfg = _SPEC_HEAD_CFG
    if cfg.d_model < head_cfg.proj_dim:
        head_cfg = SketchHeadConfig(
            n_rows=head_cfg.n_rows, n_buckets=head_cfg.n_buckets,
            k=head_cfg.k, proj_dim=cfg.d_model,
            bandwidth=head_cfg.bandwidth)
    prompts = jnp.asarray(np.stack([p for p, _ in reqs]))
    seqs = generate(params, cfg, prompts, gen_long)
    hiddens, _, _ = forward(params, seqs, cfg, return_hidden=True)
    hiddens = jnp.reshape(hiddens, (-1, cfg.d_model))
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    n_points = min(1024, hiddens.shape[0])
    kparams, _ = distill_head(
        jax.random.PRNGKey(12), table, hiddens, head_cfg,
        n_points=n_points,
        distill_cfg=DistillConfig(n_steps=distill_steps, lr=5e-3))
    return SketchHead(cfg=head_cfg, backend=backend,
                      params=freeze_head(jax.random.PRNGKey(13), kparams,
                                         head_cfg))


def _make_tenant_heads(cfg, n_tenants: int, backend: str = "fused"):
    """Per-tenant sketch banks sharing one spec (DESIGN.md §14).

    Every tenant freezes the *same* kernel params with its own PRNG key —
    the production shape (one distilled spec, per-tenant count arrays from
    per-tenant streams) without paying ``n_tenants`` distillations in a
    benchmark that only measures serving cost.  Returns the shared spec
    head plus the ``{tenant_id: params}`` archive the ``HeadCache`` loader
    pages from.
    """
    spec = _make_head(cfg, backend)
    head_cfg = spec.cfg
    key = jax.random.PRNGKey(0)
    kparams = {
        "points": jax.random.normal(key, (128, head_cfg.proj_dim)),
        "alphas": jax.random.normal(key, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(key, (cfg.d_model, head_cfg.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    archive = {f"tenant-{t}": freeze_head(jax.random.PRNGKey(100 + t),
                                          kparams, head_cfg)
               for t in range(n_tenants)}
    return spec, archive


def _run_tenants(params, cfg, reqs, n_slots, max_seq, n_tenants,
                 backend="fused", mesh=None, seed=7, zipf_a=1.1):
    """The request stream fanned across ``n_tenants`` tenants (Zipf mix)
    through a per-tenant engine whose ``HeadCache`` holds fewer banks than
    the tenant population — so the run exercises load, hit, LRU eviction
    AND reload, not just the steady state."""
    from repro.api import HeadCache

    spec, archive = _make_tenant_heads(cfg, n_tenants, backend)
    capacity = max(1, min(n_tenants, n_slots))
    rng = np.random.default_rng(seed)
    weights = 1.0 / np.arange(1, n_tenants + 1) ** zipf_a
    weights /= weights.sum()
    tenants = [f"tenant-{int(rng.choice(n_tenants, p=weights))}"
               for _ in reqs]

    def _one_pass():
        cache = HeadCache(archive.__getitem__, capacity=capacity)
        engine = make_engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                             head=spec, mesh=mesh, head_cache=cache)
        for (prompt, gen), tenant in zip(reqs, tenants):
            engine.submit(prompt, gen, tenant=tenant)
        t0 = time.perf_counter()
        finished = engine.run()
        dur = time.perf_counter() - t0
        return cache, dur, sum(len(v) for v in finished.values())

    _one_pass()                                        # warm the compile
    cache, dur, tokens = _one_pass()
    stats = dict(cache.stats)
    queries = stats["hits"] + stats["misses"]
    return {
        "requests": len(reqs), "n_tenants": n_tenants,
        "capacity": capacity, **stats,
        "hit_rate": stats["hits"] / queries if queries else 0.0,
        "seconds": dur, "tokens": tokens, "tok_s": tokens / dur,
    }


def _heavy_tail_trace(n_requests, vocab, *, seed=0, n_base=12, zipf_a=1.1,
                      plen_range=(4, 16), gen_range=(2, 10), burst_lam=0.6):
    """Heavy-tail production-style trace → ``[(prompt, gen, arrival), …]``.

    * **Zipf prompt reuse** — ``n_base`` base prompts drawn once, then each
      request picks one with weight ∝ 1/rank^``zipf_a``: a few hot prompts
      dominate (the shared-system-prompt pattern the prefix cache exists
      for), the tail stays cold.
    * **Heavy-tail lengths** — prompt lengths are power-skewed inside
      ``plen_range`` (quadratic toward short) and generation lengths inside
      ``gen_range`` (cubic toward short): most requests are small, a few
      run long — the mix where fixed-shape slots strand the most memory.
    * **Bursty Poisson arrivals** — inter-arrival gaps are
      ``Poisson(burst_lam)`` ticks, so most gaps are 0 (same-tick bursts
      that pile onto one admission round) with occasional lulls.

    Deterministic per seed, so the contiguous and paged engines replay the
    identical trace.
    """
    rng = np.random.default_rng(seed)
    plo, phi = plen_range
    base = [rng.integers(0, vocab, plo + int((phi - plo) * rng.random() ** 2),
                         dtype=np.int32) for _ in range(n_base)]
    weights = 1.0 / np.arange(1, n_base + 1) ** zipf_a
    weights /= weights.sum()
    glo, ghi = gen_range
    now = 0
    trace = []
    for _ in range(n_requests):
        prompt = base[int(rng.choice(n_base, p=weights))]
        gen = glo + int((ghi - glo) * rng.random() ** 3)
        now += int(rng.poisson(burst_lam))
        trace.append((prompt, gen, now))
    return trace


def _run_static(params, cfg, reqs, n_slots, head, mesh=None):
    """FIFO chunks of n_slots; each chunk decodes to its longest member."""
    done_tokens = 0
    decode_steps = 0
    active_slot_steps = 0
    t0 = time.perf_counter()
    for i in range(0, len(reqs), n_slots):
        chunk = reqs[i : i + n_slots]
        prompts = jnp.asarray(np.stack([p for p, _ in chunk]))
        gen_max = max(g for _, g in chunk)
        out = generate(params, cfg, prompts, gen_max, head=head, mesh=mesh)
        jax.block_until_ready(out)
        done_tokens += sum(g for _, g in chunk)   # useful tokens only
        decode_steps += gen_max - 1               # first token from prefill
        active_slot_steps += sum(g - 1 for _, g in chunk)
    dur = time.perf_counter() - t0
    util = (active_slot_steps / (decode_steps * n_slots)
            if decode_steps else 1.0)
    return {"seconds": dur, "tokens": done_tokens,
            "tok_s": done_tokens / dur, "decode_steps": decode_steps,
            "slot_utilization": util}


def _run_engine(params, cfg, reqs, n_slots, max_seq, head, mesh=None,
                decode_chunk=1, spec_decode=0):
    engine = make_engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                         head=head, mesh=mesh, decode_chunk=decode_chunk,
                         spec_decode=spec_decode)
    for prompt, gen in reqs:
        engine.submit(prompt, gen)
    t0 = time.perf_counter()
    finished = engine.run()
    dur = time.perf_counter() - t0
    tokens = sum(len(v) for v in finished.values())
    out = {"seconds": dur, "tokens": tokens, "tok_s": tokens / dur,
           "decode_steps": engine.stats["decode_steps"],
           "megasteps": engine.stats["megasteps"],
           "host_syncs_per_token": engine.stats["host_syncs"] / tokens,
           "decode_chunk": decode_chunk,
           "slot_utilization": engine.slot_utilization}
    if spec_decode:
        drafted = engine.stats["draft_tokens"]
        verifies = engine.stats["verify_calls"]
        out["spec_decode"] = spec_decode
        out["acceptance_rate"] = (
            engine.stats["accepted_draft_tokens"] / drafted if drafted
            else 0.0)
        out["accepted_tokens_per_verify"] = (
            engine.stats["accepted_draft_tokens"] / verifies if verifies
            else 0.0)
    return out


def _run_traced(params, cfg, trace, n_slots, max_seq, head, mesh=None,
                paged=False, page_size=16):
    """One engine pass over an arrival-stamped trace, recording per-request
    completion ticks for latency percentiles.

    Mirrors ``ServeEngine.run()``'s tick loop (including the idle jump to
    the next arrival) but diffs ``engine.finished`` after every step so each
    request's latency — finish tick minus arrival tick — is known.  Tick
    latencies convert to seconds via the run's mean wall-clock per tick.
    """
    engine = make_engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                         head=head, mesh=mesh, paged=paged,
                         page_size=page_size)
    arrivals = {}
    for prompt, gen, arrival in trace:
        rid = engine.submit(prompt, gen, arrival=arrival)
        arrivals[rid] = arrival
    finish = {}
    t0 = time.perf_counter()
    while engine.queue or engine.sched.n_active:
        if (not engine.sched.n_active
                and engine.queue.peek().arrival > engine.now):
            engine.now = engine.queue.peek().arrival
        done_before = len(engine.finished)
        engine.step()
        if len(engine.finished) > done_before:
            for rid in engine.finished.keys() - finish.keys():
                finish[rid] = engine.now
    dur = time.perf_counter() - t0
    lat = np.asarray([finish[r] - arrivals[r] for r in sorted(finish)],
                     float)
    sec_per_tick = dur / max(1, engine.now)
    tokens = sum(len(v) for v in engine.finished.values())
    rec = {
        "seconds": dur, "tokens": tokens, "tok_s": tokens / dur,
        "tokens_per_s_per_slot": tokens / dur / n_slots,
        "decode_steps": engine.stats["decode_steps"],
        "prefill_batches": engine.stats["prefill_batches"],
        "dedup_saved": engine.stats["dedup_saved"],
        "latency_ticks_p50": float(np.percentile(lat, 50)),
        "latency_ticks_p99": float(np.percentile(lat, 99)),
        "latency_s_p50": float(np.percentile(lat, 50) * sec_per_tick),
        "latency_s_p99": float(np.percentile(lat, 99) * sec_per_tick),
    }
    if paged:
        s = engine.stats
        rec.update({
            "prefix_hit_rate": (s["prefix_hits"] / s["prefix_queries"]
                                if s["prefix_queries"] else 0.0),
            "prefix_hits": s["prefix_hits"],
            "prefix_queries": s["prefix_queries"],
            "pages_in_use_peak": s["pages_in_use_peak"],
            "page_allocs": s["page_allocs"],
            "cow_copies": s["cow_copies"],
        })
    return rec, engine.finished


def _run_heavy_tail(params, cfg, trace, n_slots, max_seq, head, mesh=None,
                    page_size=16):
    """The full heavy-tail trace through the contiguous engine and the
    paged engine (launch/paging.py, DESIGN.md §13), asserting the paged run
    reproduced the contiguous token streams bitwise and prefilled less."""
    # Warm both paths on one request per distinct prompt length first:
    # prefill executables specialize on prompt length, and without this the
    # first run (contiguous) would eat every compile inside its timed
    # region while the second (paged) reused them all.
    warm = {len(p): (p, 2, 0) for p, _, _ in trace}
    _run_traced(params, cfg, list(warm.values()), n_slots, max_seq, head,
                mesh)
    _run_traced(params, cfg, list(warm.values()), n_slots, max_seq, head,
                mesh, paged=True, page_size=page_size)
    contiguous, out_c = _run_traced(params, cfg, trace, n_slots, max_seq,
                                    head, mesh)
    paged, out_p = _run_traced(params, cfg, trace, n_slots, max_seq, head,
                               mesh, paged=True, page_size=page_size)
    outputs_match = out_c == out_p
    assert outputs_match, (
        "paged engine diverged from the contiguous engine on the same "
        "trace: " + str([r for r in out_c if out_c[r] != out_p[r]][:4]))
    assert paged["prefill_batches"] <= contiguous["prefill_batches"]
    if paged["prefix_hits"]:
        assert paged["prefill_batches"] < contiguous["prefill_batches"], (
            "prefix hits recorded but the paged run prefilled as often as "
            "the contiguous one")
    return {
        "requests": len(trace), "page_size": page_size,
        "contiguous": contiguous, "paged": paged,
        "outputs_match": outputs_match,
        "prefix_hit_rate": paged["prefix_hit_rate"],
        "pages_in_use_peak": paged["pages_in_use_peak"],
        "prefill_batches": paged["prefill_batches"],
        "prefill_batches_contiguous": contiguous["prefill_batches"],
        "tok_s": paged["tok_s"],
        "tokens_per_s_per_slot": paged["tokens_per_s_per_slot"],
        "latency_ticks_p50": paged["latency_ticks_p50"],
        "latency_ticks_p99": paged["latency_ticks_p99"],
        "latency_s_p50": paged["latency_s_p50"],
        "latency_s_p99": paged["latency_s_p99"],
    }


def run(arch: str = "rwkv6-1.6b", n_slots: int = 4, n_requests: int = 16,
        prompt_len: int = 8, gen_short: int = 4, gen_long: int = 64,
        reps: int = 3, backend: str = "fused", mesh=None,
        chunks=(1, 4, 16), spec_ks=(1, 4, 16), distill_steps: int = 300,
        ht_requests: int = 1000, page_size: int = 16, n_tenants: int = 8):
    from benchmarks.schema import SCHEMA_VERSION, mesh_record
    from repro.launch.mesh import parse_mesh

    mesh = parse_mesh(mesh)
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head = _make_head(cfg, backend)
    if mesh is not None:
        # Place once, outside the timed loops — the per-call device_puts
        # inside generate()/make_engine become no-ops, so neither mode pays
        # host→device placement inside its timed region.
        from repro.launch.mesh import place_serving_state
        params, head = place_serving_state(params, head, mesh)
    max_seq = prompt_len + gen_long
    # Sweep stream: the heavy-tail generator with the prompt length pinned
    # (static batching stacks its chunk into one (B, P) array) and arrivals
    # dropped (all three comparison modes see the full backlog at t=0 — the
    # throughput protocol; the heavy_tail section below honors arrivals).
    reqs = [(p, g) for p, g, _ in _heavy_tail_trace(
        n_requests, cfg.vocab_size, plen_range=(prompt_len, prompt_len),
        gen_range=(gen_short, gen_long))]

    # Warm both paths (compile) on a tiny slice, then time the full stream
    # rep-by-rep interleaved (machine-load drift hits both modes equally)
    # and keep the best rep of each.
    _run_static(params, cfg, reqs[: 2 * n_slots], n_slots, head, mesh)
    _run_engine(params, cfg, reqs[: 2 * n_slots], n_slots, max_seq, head,
                mesh)

    static = engine = None
    for _ in range(reps):
        s = _run_static(params, cfg, reqs, n_slots, head, mesh)
        e = _run_engine(params, cfg, reqs, n_slots, max_seq, head, mesh)
        static = s if static is None or s["seconds"] < static["seconds"] else static
        engine = e if engine is None or e["seconds"] < engine["seconds"] else engine

    # Megastep sweep: the same stream through the engine at each chunk
    # size K — K=1 is the per-token host tick the parity tests pin, larger
    # K amortizes the per-token dispatch + device→host sample sync over an
    # on-device lax.scan (launch/decode_loop.py, DESIGN.md §10).
    megastep = {}
    for k in chunks:
        if k == 1:
            # Identical protocol to the engine comparison runs above (same
            # stream, decode_chunk=1, best-of-reps) — reuse, don't re-time.
            megastep["1"] = engine
            continue
        _run_engine(params, cfg, reqs[: 2 * n_slots], n_slots, max_seq,
                    head, mesh, decode_chunk=k)          # warm the compile
        best = None
        for _ in range(reps):
            m = _run_engine(params, cfg, reqs, n_slots, max_seq, head,
                            mesh, decode_chunk=k)
            best = m if best is None or m["seconds"] < best["seconds"] else best
        megastep[str(k)] = best

    # Speculative sweep: distilled sketch head drafts, dense verifies
    # (DESIGN.md §11).  The random _make_head head would accept ~1/V of
    # drafts — it measures nothing — so the spec rows distill in-process,
    # on hiddens harvested from the benchmark's own (dense, greedy) decode
    # stream rather than random gaussians: acceptance is a property of the
    # serving distribution, and the stream's hiddens are the distribution
    # the draft head will actually see.  The dense_megastep rows are the
    # fair baseline the §11 speedup claim is judged against: plain chunked
    # dense decode at the same K.
    from repro.api.heads import DenseHead

    # The draft head times the ref (jnp) path: interpret-mode Pallas is not
    # a TPU proxy (same protocol as sketch_head_bench), and at L=512 rows
    # its per-call overhead would swamp the acceptance signal entirely.
    spec_head = _distill_spec_head(params, cfg, reqs, gen_long, "ref",
                                   distill_steps=distill_steps)
    if mesh is not None:
        from repro.launch.mesh import place_serving_state
        _, spec_head = place_serving_state(params, spec_head, mesh)
    spec_sweep, dense_sweep = {}, {}
    for k in spec_ks:
        _run_engine(params, cfg, reqs[: 2 * n_slots], n_slots, max_seq,
                    spec_head, mesh, spec_decode=k)      # warm the compile
        best = None
        for _ in range(reps):
            s = _run_engine(params, cfg, reqs, n_slots, max_seq, spec_head,
                            mesh, spec_decode=k)
            best = s if best is None or s["seconds"] < best["seconds"] else best
        spec_sweep[str(k)] = best
        _run_engine(params, cfg, reqs[: 2 * n_slots], n_slots, max_seq,
                    DenseHead(), mesh, decode_chunk=k)
        dbest = None
        for _ in range(reps):
            d = _run_engine(params, cfg, reqs, n_slots, max_seq,
                            DenseHead(), mesh, decode_chunk=k)
            dbest = d if dbest is None or d["seconds"] < dbest["seconds"] else dbest
        dense_sweep[str(k)] = dbest

    # Heavy-tail latency protocol: the full variable-prompt-length trace
    # with arrivals honored, contiguous vs paged engine (DESIGN.md §13).
    # The paged run is warmed implicitly — it reuses the decode executable
    # the sweeps above compiled (merged view == contiguous cache structure);
    # only the gather/commit/insert page ops compile fresh, once.
    ht_trace = _heavy_tail_trace(ht_requests, cfg.vocab_size)
    ht_max_seq = max(len(p) + g for p, g, _ in ht_trace)
    heavy_tail = _run_heavy_tail(params, cfg, ht_trace, n_slots, ht_max_seq,
                                 head, mesh, page_size=page_size)

    # Per-tenant serving (DESIGN.md §14): the same throughput stream fanned
    # across a Zipf tenant mix, heads paged through an LRU HeadCache with
    # capacity = min(n_tenants, n_slots) so cold tenants force evictions.
    tenants = _run_tenants(params, cfg, reqs, n_slots, max_seq, n_tenants,
                           backend=backend, mesh=mesh)

    result = {
        "schema_version": SCHEMA_VERSION,
        "mesh": mesh_record(mesh),
        "decode_chunk": 1,   # the static-vs-engine comparison rows' chunk
        "arch": cfg.name, "n_slots": n_slots, "n_requests": n_requests,
        "prompt_len": prompt_len, "gen_short": gen_short,
        "gen_long": gen_long,
        "heavy_tail": heavy_tail,
        "tenants": tenants,
        "head": {"kind": head.kind, "backend": head.backend},
        "static": static, "engine": engine,
        "megastep": megastep,
        "spec_decode": spec_sweep,
        "dense_megastep": dense_sweep,
        "spec_head": {"kind": spec_head.kind, "backend": spec_head.backend,
                      "distill_steps": distill_steps,
                      "distilled_on": "stream_hiddens",
                      "n_rows": spec_head.cfg.n_rows,
                      "n_buckets": spec_head.cfg.n_buckets,
                      "k": spec_head.cfg.k,
                      "proj_dim": spec_head.cfg.proj_dim,
                      "bandwidth": spec_head.cfg.bandwidth},
        "tok_s_speedup": engine["tok_s"] / static["tok_s"],
        "decode_step_ratio": static["decode_steps"] / engine["decode_steps"],
        "note": "same skewed request stream (alternating gen_short/gen_long)"
                " served as FIFO static chunks vs the continuous-batching"
                " engine; tokens counts useful (per-request) tokens only, so"
                " tok_s differences are padding waste vs slot recycling."
                " megastep[K] reruns the engine with decode_chunk=K"
                " (on-device K-token scan).  spec_decode[K] is speculative"
                " self-decode (sketch head distilled on the stream's own"
                " hiddens drafts K, one batched dense pass verifies; output"
                " bitwise == dense) and dense_megastep[K] its plain"
                " chunked-dense baseline (schema v4).  At the smoke scale"
                " the draft head is NOT cheaper than the dense unembed"
                " (n_rows > d_model — rows are spent on acceptance, the"
                " frozen RACE estimate's 1/L variance bounds argmax"
                " agreement) and commits are lockstep (min over slots), so"
                " spec tok/s trails the dense megastep here; the §11 win"
                " condition is the paper-scale L ≪ d regime with"
                " near-full acceptance.  heavy_tail (schema v6) replays a"
                " Zipf-reuse / bursty-arrival / variable-length trace"
                " through the contiguous and the paged engine (DESIGN.md"
                " §13): outputs verified bitwise equal, latency percentiles"
                " are ticks-since-arrival (seconds via mean tick time), and"
                " the paged run's prefill_batches drop is the prefix cache"
                " skipping repeated prompts' prefills.  tenants (schema v7)"
                " fans the throughput stream across a Zipf tenant mix"
                " served through per-slot tenant head bindings (DESIGN.md"
                " §14): banks page through an LRU HeadCache smaller than"
                " the tenant population, so the counters cover load, hit,"
                " eviction and reload, not just the resident steady state.",
    }
    print(f"  static:  {static['tok_s']:8.1f} tok/s  "
          f"({static['decode_steps']} decode steps, "
          f"util {static['slot_utilization']:.2f})")
    print(f"  engine:  {engine['tok_s']:8.1f} tok/s  "
          f"({engine['decode_steps']} decode steps, "
          f"util {engine['slot_utilization']:.2f})")
    print(f"  speedup: {result['tok_s_speedup']:.2f}x tok/s, "
          f"{result['decode_step_ratio']:.2f}x fewer decode steps")
    for k, m in megastep.items():
        print(f"  megastep K={k:>2}: {m['tok_s']:8.1f} tok/s  "
              f"({m['decode_steps']} decode steps in {m['megasteps']} "
              f"dispatches, {m['host_syncs_per_token']:.2f} host syncs/tok)")
    for k in spec_sweep:
        s, d = spec_sweep[k], dense_sweep[k]
        print(f"  spec K={k:>2}: {s['tok_s']:8.1f} tok/s  "
              f"(acceptance {s['acceptance_rate']:.2f}, "
              f"{s['accepted_tokens_per_verify']:.2f} acc tok/verify) "
              f"vs dense megastep {d['tok_s']:8.1f} tok/s")
    ht = heavy_tail
    for mode in ("contiguous", "paged"):
        m = ht[mode]
        print(f"  heavy-tail {mode:>10}: {m['tok_s']:8.1f} tok/s "
              f"({m['tokens_per_s_per_slot']:.1f}/slot), latency p50/p99 "
              f"{m['latency_ticks_p50']:.0f}/{m['latency_ticks_p99']:.0f} "
              f"ticks, {m['prefill_batches']} prefill batches")
    print(f"  heavy-tail paged: prefix hit rate "
          f"{ht['prefix_hit_rate']:.2f}, pages in use peak "
          f"{ht['pages_in_use_peak']}, outputs bitwise equal: "
          f"{ht['outputs_match']}")
    tn = tenants
    print(f"  tenants: {tn['n_tenants']} over HeadCache capacity "
          f"{tn['capacity']}: {tn['tok_s']:8.1f} tok/s, hit rate "
          f"{tn['hit_rate']:.2f} ({tn['hits']} hits / {tn['misses']} "
          f"misses), {tn['loads']} loads, {tn['evictions']} evictions")
    BENCH_JSON.write_text(json.dumps(result, indent=1))
    print(f"  wrote {BENCH_JSON}")
    return result


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="static vs engine + decode-megastep chunk sweep")
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-short", type=int, default=4)
    ap.add_argument("--gen-long", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "two_kernel", "ref"])
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--chunks", default="1,4,16",
                    help="comma list of decode_chunk sizes to sweep")
    ap.add_argument("--spec-decode", default="1,4,16",
                    help="comma list of speculative draft lengths to sweep "
                         "(DESIGN.md §11)")
    ap.add_argument("--distill-steps", type=int, default=300,
                    help="in-process distillation budget for the spec "
                         "sweep's sketch head")
    ap.add_argument("--ht-requests", type=int, default=1000,
                    help="heavy-tail trace length (contiguous-vs-paged "
                         "latency section; DESIGN.md §13)")
    ap.add_argument("--paged", action="store_true",
                    help="no-op marker: the heavy-tail section always runs "
                         "both the contiguous and the paged engine (shrink "
                         "it with --ht-requests)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per cache page for the heavy-tail paged "
                         "run")
    ap.add_argument("--tenants", type=int, default=8,
                    help="tenant population for the per-tenant HeadCache "
                         "section (DESIGN.md §14)")
    args = ap.parse_args()
    run(arch=args.arch, n_slots=args.n_slots, n_requests=args.requests,
        prompt_len=args.prompt_len, gen_short=args.gen_short,
        gen_long=args.gen_long, reps=args.reps, backend=args.backend,
        mesh=args.mesh,
        chunks=tuple(int(c) for c in args.chunks.split(",")),
        spec_ks=tuple(int(c) for c in args.spec_decode.split(",")),
        distill_steps=args.distill_steps, ht_requests=args.ht_requests,
        page_size=args.page_size, n_tenants=args.tenants)


if __name__ == "__main__":
    main()
