"""Sketched LM head vs dense head: wall-clock on CPU + analytic TPU terms.

The analytic terms are the deployment-relevant comparison (CPU interpret-
mode Pallas timing is not a TPU proxy); wall-clock is still reported for the
pure-jnp paths.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch_lm_head import apply_head, freeze_head, head_costs
from repro.models.config import SketchHeadConfig


def _time(fn, *args, n=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(d_model: int = 1024, vocab: int = 32768, batch: int = 8):
    cfg = SketchHeadConfig(n_rows=64, n_buckets=16, k=2, proj_dim=64,
                           bandwidth=4.0)
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
    hidden = jax.random.normal(key, (batch, d_model))

    # Direct-construction head (distillation quality is covered by
    # tests/test_system.py; here we measure cost).
    kparams = {
        "points": jax.random.normal(key, (512, cfg.proj_dim)),
        "alphas": jax.random.normal(key, (512, vocab)) * 0.01,
        "proj": jax.random.normal(key, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    head = freeze_head(key, kparams, cfg)

    dense = jax.jit(lambda h: h @ table.T)
    sketch = jax.jit(lambda h: apply_head(head, h, cfg, use_pallas=False))

    us_dense = _time(dense, hidden)
    us_sketch = _time(sketch, hidden)
    costs = head_costs(cfg, d_model, vocab)
    print(f"  dense head: {us_dense:9.1f} us/call   "
          f"sketch head: {us_sketch:9.1f} us/call (cpu jnp)")
    print(f"  params: dense {costs['dense_params']/1e6:.1f}M vs sketch "
          f"{costs['sketch_params']/1e6:.1f}M  ({costs['param_ratio']:.1f}x)")
    print(f"  flops/token: dense {costs['dense_flops']/1e6:.2f}M vs sketch "
          f"{costs['sketch_flops']/1e6:.2f}M  ({costs['flop_ratio']:.1f}x)")
    return {"us_dense": us_dense, "us_sketch": us_sketch, **costs}
