"""Sketched LM head vs dense head: dense vs two-kernel vs fused decode.

Times the three serving decode paths —

  dense      h @ Wᵀ                                   (the baseline matmul)
  2-kernel   lsh_hash → HBM (B, L) idx → sketch_head  (separate kernels)
  fused      one pallas_call: transform→hash→gather   (repro.kernels.fused_decode)

— and emits ``BENCH_sketch_serve.json`` (schema v5) at the repo root.
Wall-clock is the jnp/ref path on CPU (interpret-mode Pallas timing is not
a TPU proxy); the analytic FLOP/byte terms are the deployment-relevant
comparison, including the HBM round trip on the index tensor that fusion
eliminates.  The v4 ``spec_decode`` section measures the head as a
*speculative draft model* (DESIGN.md §11): a distilled head's greedy
agreement with the dense argmax over K-token blocks gives the
``acceptance_rate`` / ``accepted_tokens_per_verify`` a spec-decode serving
loop would see at this head quality.  The v5 ``quant_curve`` section is
the accuracy-vs-bits trade-off of quantized count-array storage
(DESIGN.md §12): per mode (f32 / int8 / int4), the logit MAE and argmax
agreement against the f32 head plus the dtype-aware storage ratio vs the
dense unembed — the paper's storage-reduction claim in one table.
``--quant int8|int4`` additionally *serves* the timed sketch paths from
quantized storage:

  PYTHONPATH=src python -m benchmarks.sketch_head_bench --quant int8
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sketch_lm_head import (apply_head, freeze_head, head_costs,
                                       quantize_head)
from repro.models.config import SketchHeadConfig

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_sketch_serve.json"


def _time(fn, *args, n=20, reps=3):
    """Best-of-``reps`` mean over ``n`` calls (min filters scheduler noise)."""
    return _time_group([fn], *args, n=n, reps=reps)[0]


def _time_group(fns, *args, n=20, reps=5):
    """Time several paths interleaved rep-by-rep so machine-load drift hits
    all of them equally (the two sketch paths differ by µs of dispatch under
    an identical dominant term — sequential timing would just measure
    drift).  Returns best-of-reps us/call per fn."""
    for fn in fns:
        jax.block_until_ready(fn(*args))  # compile
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(n):
                out = fn(*args)
            jax.block_until_ready(out)
            best[i] = min(best[i], (time.perf_counter() - t0) / n)
    return [b * 1e6 for b in best]


def _spec_agreement(table, cfg, d_model, vocab, spec_k: int = 4,
                    n_eval: int = 512, distill_steps: int = 300) -> dict:
    """Greedy draft-acceptance stats for a distilled head (schema v4).

    Distills a head against ``table`` (the quality path the serving loop's
    in-process distillation uses), then measures argmax agreement with the
    dense logits over ``n_eval`` hiddens grouped into K-token blocks: the
    leading-match run per block is exactly what greedy spec-decode commits
    per verify (DESIGN.md §11, minus the free bonus token).
    """
    from repro.core.distill import DistillConfig
    from repro.core.sketch_lm_head import apply_head, distill_head

    hiddens = jax.random.normal(jax.random.PRNGKey(11), (1024, d_model))
    kparams, _ = distill_head(
        jax.random.PRNGKey(12), table, hiddens, cfg, n_points=256,
        distill_cfg=DistillConfig(n_steps=distill_steps, lr=5e-3))
    frozen = freeze_head(jax.random.PRNGKey(13), kparams, cfg)

    ev = jax.random.normal(jax.random.PRNGKey(14), (n_eval, d_model))
    dense_tok = jnp.argmax(ev @ table.T, axis=-1)
    sketch_tok = jnp.argmax(
        apply_head(frozen, ev, cfg, backend="ref", kernel_backend="ref"),
        axis=-1)
    match = np.asarray(dense_tok == sketch_tok)
    blocks = match[: (len(match) // spec_k) * spec_k].reshape(-1, spec_k)
    leading = np.cumprod(blocks, axis=1).sum(axis=1)   # accepted per verify
    return {"k": spec_k,
            "acceptance_rate": float(leading.mean() / spec_k),
            "accepted_tokens_per_verify": float(leading.mean()),
            "argmax_agreement": float(match.mean()),
            "distill_steps": distill_steps, "n_eval": int(n_eval)}


def _quant_curve(head: dict, cfg, d_model: int, vocab: int,
                 n_eval: int = 256) -> dict:
    """Accuracy-vs-bits table for quantized count storage (schema v5).

    Per storage mode: logit MAE and argmax agreement vs the f32 head on a
    shared eval batch, plus the dtype-aware dense/sketch bytes ratio.
    """
    ev = jax.random.normal(jax.random.PRNGKey(21), (n_eval, d_model))
    base = apply_head(head, ev, cfg, backend="ref")
    base_tok = jnp.argmax(base, axis=-1)
    curve = {}
    for quant in (None, "int8", "int4"):
        qhead = quantize_head(head, quant)
        out = apply_head(qhead, ev, cfg, backend="ref", quant=quant)
        costs = head_costs(cfg, d_model, vocab, quant=quant)
        curve["f32" if quant is None else quant] = {
            "logit_mae": float(jnp.abs(out - base).mean()),
            "top1_agreement": float(
                (jnp.argmax(out, axis=-1) == base_tok).mean()),
            "sketch_bytes": costs["sketch_bytes"],
            "bytes_ratio": costs["bytes_ratio"],
        }
    return curve


def run(d_model: int = 1024, vocab: int = 32768, batch: int = 8,
        backend: str = "fused", mesh=None, quant=None):
    from benchmarks.schema import SCHEMA_VERSION, mesh_record
    from repro.launch.mesh import parse_mesh

    mesh = parse_mesh(mesh)
    cfg = SketchHeadConfig(n_rows=64, n_buckets=16, k=2, proj_dim=64,
                           bandwidth=4.0)
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
    hidden = jax.random.normal(key, (batch, d_model))

    # Direct-construction head (distillation quality is covered by
    # tests/test_system.py; here we measure cost).
    kparams = {
        "points": jax.random.normal(key, (512, cfg.proj_dim)),
        "alphas": jax.random.normal(key, (512, vocab)) * 0.01,
        "proj": jax.random.normal(key, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    head = freeze_head(key, kparams, cfg)
    # ``quant`` serves the timed sketch paths from quantized storage —
    # the deployable artifact of DESIGN.md §12 (f32 counts stay around
    # only to build the accuracy curve below).
    qhead = quantize_head(head, quant)

    dense = jax.jit(lambda h: h @ table.T)
    sketch_jit = jax.jit(
        lambda h: apply_head(qhead, h, cfg, backend=backend,
                             kernel_backend="ref", quant=quant))
    # Dispatch-level comparison: what fusion actually removes is the kernel
    # boundary — two launches with the (B, L) idx tensor materialized
    # between them vs one launch.  (Under a single outer jit the two ref
    # paths compile to the same graph, so they are *not* compared there.)
    two_kernel = lambda h: apply_head(qhead, h, cfg, backend="two_kernel",
                                      kernel_backend="ref", quant=quant)
    fused = lambda h: apply_head(qhead, h, cfg, backend="fused",
                                 kernel_backend="ref", quant=quant)

    us_dense = _time(dense, hidden)
    us_sketch, us_two, us_fused = _time_group(
        [sketch_jit, two_kernel, fused], hidden)
    us_sharded = None
    if mesh is not None:
        # The row-sharded shard_map path (DESIGN.md §9): count arrays over
        # model on the repetition axis, one psum of (B, V) per call.  On
        # forced-CPU devices this measures dispatch overhead, not a TPU
        # win; the record's mesh field is the point.
        from repro.sharding.rules import head_param_shardings
        placed = jax.device_put(qhead, head_param_shardings(qhead, mesh))
        sharded = jax.jit(lambda h: apply_head(placed, h, cfg,
                                               backend=backend,
                                               kernel_backend="ref",
                                               quant=quant, mesh=mesh))
        us_sharded = _time(sharded, hidden)
    spec = _spec_agreement(table, cfg, d_model, vocab)
    curve = _quant_curve(head, cfg, d_model, vocab)
    costs = head_costs(cfg, d_model, vocab, quant=quant)
    # HBM traffic the fusion removes: write + read of the (B, L) int32 index
    # tensor between the lsh_hash and sketch_head kernel launches.
    idx_bytes = 2 * batch * cfg.n_rows * 4

    tok_s = lambda us: batch / (us * 1e-6)
    print(f"  dense (jit):    {us_dense:9.1f} us/call  ({tok_s(us_dense):10.0f} tok/s)")
    print(f"  sketch (jit):   {us_sketch:9.1f} us/call  ({tok_s(us_sketch):10.0f} tok/s)")
    print(f"  2-kernel path:  {us_two:9.1f} us/call  ({tok_s(us_two):10.0f} tok/s)"
          f"  [2 launches + (B, L) idx materialized]")
    print(f"  fused path:     {us_fused:9.1f} us/call  ({tok_s(us_fused):10.0f} tok/s)"
          f"  [1 launch; idx round trip saved: {idx_bytes} B/step]")
    print(f"  params: dense {costs['dense_params']/1e6:.1f}M vs sketch "
          f"{costs['sketch_params']/1e6:.1f}M  ({costs['param_ratio']:.1f}x)")
    print(f"  bytes (quant={quant}): dense {costs['dense_bytes']/1e6:.1f}MB "
          f"vs sketch {costs['sketch_bytes']/1e6:.1f}MB  "
          f"({costs['bytes_ratio']:.2f}x)")
    for mode, e in curve.items():
        print(f"  quant_curve[{mode}]: logit_mae {e['logit_mae']:.4f}, "
              f"top1_agreement {e['top1_agreement']:.3f}, "
              f"bytes_ratio {e['bytes_ratio']:.2f}x")
    print(f"  flops/token: dense {costs['dense_flops']/1e6:.2f}M vs sketch "
          f"{costs['sketch_flops']/1e6:.2f}M  ({costs['flop_ratio']:.1f}x)")
    print(f"  spec draft (K={spec['k']}, distilled): acceptance "
          f"{spec['acceptance_rate']:.2f}, "
          f"{spec['accepted_tokens_per_verify']:.2f} accepted tok/verify "
          f"(argmax agreement {spec['argmax_agreement']:.2f})")

    result = {
        "schema_version": SCHEMA_VERSION,
        "mesh": mesh_record(mesh),
        # Per-call head microbenchmark: one head application per record row,
        # i.e. the host-loop serving shape (schema v3 field).
        "decode_chunk": 1,
        "d_model": d_model, "vocab": vocab, "batch": batch,
        "head": {"kind": "sketch", "backend": backend, "quant": quant},
        "head_config": {"n_rows": cfg.n_rows, "n_buckets": cfg.n_buckets,
                        "k": cfg.k, "proj_dim": cfg.proj_dim,
                        "bandwidth": cfg.bandwidth},
        "us_dense": us_dense,
        "us_sketch": us_sketch,
        "us_two_kernel": us_two,
        "us_fused": us_fused,
        "tok_s_dense": tok_s(us_dense),
        "tok_s_two_kernel": tok_s(us_two),
        "tok_s_fused": tok_s(us_fused),
        "fused_vs_two_kernel_speedup": us_two / us_fused,
        "us_sharded": us_sharded,
        "idx_hbm_bytes_saved_per_step": idx_bytes,
        "spec_decode": spec,
        "quant_curve": curve,
        "note": "us_two_kernel/us_fused are dispatch-level (kernel-boundary)"
                " timings of the jnp reference paths on CPU; under one jit"
                " both lower to the same graph, and interpret-mode Pallas is"
                " not a TPU proxy — the analytic flop/byte terms are the"
                " deployment comparison.  spec_decode measures a distilled"
                " head's greedy draft acceptance against the dense argmax"
                " over K-token blocks (DESIGN.md §11; schema v4)."
                "  quant_curve is the accuracy-vs-bits trade-off of"
                " quantized count storage vs the f32 head on a shared eval"
                " batch; bytes fields are the dtype-aware storage"
                " comparison at this record's serving quant mode"
                " (DESIGN.md §12; schema v5).",
        **costs,
    }
    BENCH_JSON.write_text(json.dumps(result, indent=1))
    print(f"  wrote {BENCH_JSON}")
    return result


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="sketched-head serving microbenchmark "
                    "(BENCH_sketch_serve.json)")
    ap.add_argument("--backend", default="fused",
                    choices=["fused", "two_kernel", "ref"])
    ap.add_argument("--quant", default=None, choices=["int8", "int4"],
                    help="serve the timed sketch paths from quantized "
                         "count-array storage (DESIGN.md §12); the "
                         "quant_curve section is emitted either way")
    ap.add_argument("--mesh", default=None,
                    help="'<data>x<model>' serving mesh (e.g. 4x2)")
    args = ap.parse_args(argv)
    run(backend=args.backend, mesh=args.mesh, quant=args.quant)


if __name__ == "__main__":
    main()
