"""Kernel micro-benchmarks: pure-jnp reference paths, us/call on CPU.

Pallas timings in interpret mode are not TPU-representative and are
excluded; the TPU-relevant cost model for the kernels is the roofline math
in sketch_head_bench / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.lsh_hash.ref import lsh_hash_ref
from repro.kernels.race_query.ref import race_query_ref
from repro.kernels.race_update.ref import race_update_ref
from repro.kernels.sketch_head.ref import sketch_head_ref


def _time(fn, *args, n=30):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    key = jax.random.PRNGKey(0)
    b, d, l, k, r, c, m, v = 128, 64, 400, 2, 16, 2, 1024, 4096
    x = jax.random.normal(key, (b, d))
    w = jax.random.normal(key, (l, k, d))
    bias = jax.random.uniform(key, (l, k))
    sketch = jax.random.normal(key, (c, l, r))
    idx = jax.random.randint(key, (b, l), 0, r)
    alphas = jax.random.normal(key, (m, c))
    midx = jax.random.randint(key, (m, l), 0, r)
    hsk = jax.random.normal(key, (l, r, v))

    rows = {
        "lsh_hash": _time(jax.jit(
            lambda xx: lsh_hash_ref(xx, w, bias, 1.0, r)), x),
        "race_query": _time(jax.jit(
            lambda ss, ii: race_query_ref(ss, ii, 8)), sketch, idx),
        "race_update": _time(jax.jit(
            lambda ii, aa: race_update_ref(jnp.zeros((c, l, r)), ii, aa)),
            midx, alphas),
        "sketch_head": _time(jax.jit(
            lambda ss, ii: sketch_head_ref(ss, ii)), hsk, idx),
    }
    for name, us in rows.items():
        print(f"  {name:12s} {us:10.1f} us/call")
    return rows
