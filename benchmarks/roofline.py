"""§Roofline: three-term analysis per (arch × shape × mesh) from the dry-run.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and computes,
per cell, **per-device seconds** for

    compute    = HLO_dot_FLOPs / peak_FLOPs          (197 TF bf16 / chip)
    memory     = HLO_bytes_accessed / HBM_bw         (819 GB/s / chip)
    collective = collective_bytes / ICI_bw           (~50 GB/s per link;
                 a 2D-torus chip drives ~4 links → 200 GB/s injection,
                 we report the conservative single-link figure too)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-
compute ratio MODEL/HLO.  The dominant term is the bottleneck the §Perf
loop iterates on.  NOTE: the CPU backend upcasts bf16 arithmetic to f32
before SPMD partitioning, so byte-based terms are ≤2× above their TPU
deployment values for activation traffic (dtype noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models.config import active_param_count, param_count

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9               # bytes/s / chip
ICI_LINK = 50e9              # bytes/s per link
ICI_LINKS = 4                # usable links per chip on a 2D torus

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    seq, batch, kind = SHAPES[shape]
    n = active_param_count(cfg)
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n * tokens
    # decode: one token per sequence (+ attention reads, excluded from the
    # 2·N model since they're memory- not FLOP-dominated)
    return 2.0 * n * batch


def analyze_cell(path: Path) -> dict:
    r = json.loads(path.read_text())
    chips = r["n_devices"]
    comp = r["flops"] / PEAK_FLOPS
    # bf16-adjusted bytes when available (CPU backend f32-legalizes bf16
    # before the HLO we parse; raw bytes kept in the JSON for reference).
    mem = r.get("bytes_bf16adj", r["bytes_accessed"]) / HBM_BW
    coll = r["collective_bytes"]["total"] / (ICI_LINK * ICI_LINKS)
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda t: t[1])
    mf = model_flops(r["arch"], r["shape"]) / chips
    return {
        **{k: r[k] for k in ("arch", "shape", "mesh", "n_devices")},
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "bottleneck": dom[0], "step_lower_bound_s": dom[1],
        "model_flops_per_chip": mf,
        "useful_ratio": mf / r["flops"] if r["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / dom[1] if dom[1] else 0.0,
        "temp_bytes": r["memory_analysis"]["temp_size_bytes"],
    }


def run(mesh: str = "single", write_md: bool = True):
    rows = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        try:
            rows.append(analyze_cell(p))
        except Exception as e:  # noqa: BLE001
            print(f"  skip {p.name}: {e!r}")
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"  {'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'bottleneck':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        print(f"  {r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.4f} "
              f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
              f"{r['bottleneck']:>10s} {r['useful_ratio']:7.2f} "
              f"{100 * r['roofline_fraction']:6.1f}%")
    if write_md and rows:
        out = RESULTS.parent / f"roofline_{mesh}.md"
        lines = ["| arch | shape | compute s | memory s | collective s | "
                 "bottleneck | useful ratio | roofline % |",
                 "|---|---|---|---|---|---|---|---|"]
        for r in rows:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
                f"{100 * r['roofline_fraction']:.1f}% |")
        out.write_text("\n".join(lines) + "\n")
        print(f"  wrote {out}")
    return rows
