"""Property-based scheduler invariants for the continuous-batching engine.

The engine's model compute hides behind the ``EngineBackend`` seam, so a
numpy-only fake backend drives the *real* admission/decode/retire control
flow under random traffic (arrival times × prompt lengths × generation
lengths) fast enough for hypothesis.  Invariants:

* no slot is ever double-assigned, and free ∪ occupied is always a partition
  of the pool;
* every admitted request retires exactly once, with exactly
  ``max_new_tokens`` tokens — or fewer when its stream hits EOS;
* ``slot_reset`` leaves a recycled slot's cache bitwise identical to a
  freshly initialized one (real cache families, random contents).

Marked slow: tier-1 (-m "not slow") stays fast.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests are skipped without hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.launch.engine import ServeEngine

pytestmark = pytest.mark.slow

_SMALL = settings(max_examples=25, deadline=None)
_VOCAB = 17


class FakeBackend:
    """Deterministic numpy backend whose per-slot "cache" is a scalar
    counter: prefill sets it to ``last_prompt_token + 1`` and every active
    decode step increments it; the emitted token IS the (modded) counter.
    Each request's stream is the closed form ``(last + 1 + i) % vocab`` —
    checkable without a model — and, because decode reads the *pool* rather
    than the fed-back token, any insert/reset/active-mask bug that corrupts
    a slot's cache corrupts the stream and fails the test (a token-echo fake
    would mask such bugs)."""

    vocab_size = _VOCAB

    def init_pool(self, n_slots, max_seq):
        return np.zeros(n_slots, np.int64)

    def prefill(self, prompts, max_seq):
        prompts = np.asarray(prompts)
        state = prompts[:, -1].astype(np.int64) + 1  # "filled cache" rows
        logits = np.zeros((prompts.shape[0], _VOCAB), np.float32)
        logits[np.arange(len(state)), state % _VOCAB] = 1.0
        return logits, state

    def insert(self, pool, filled, slots):
        pool = pool.copy()
        pool[np.asarray(slots)] = filled
        return pool

    def reset(self, pool, slots):
        pool = pool.copy()
        pool[np.asarray(slots)] = 0
        return pool

    def decode(self, pool, tokens, pos, active):
        nxt = (pool + 1) % _VOCAB
        logits = np.zeros((len(nxt), _VOCAB), np.float32)
        logits[np.arange(len(nxt)), nxt] = 1.0
        pool = np.where(active, pool + 1, pool)  # inactive rows untouched
        return logits, pool


@st.composite
def _traffic(draw):
    n_slots = draw(st.integers(1, 4))
    n_requests = draw(st.integers(1, 8))
    reqs = []
    for _ in range(n_requests):
        reqs.append((draw(st.integers(1, 5)),        # prompt len
                     draw(st.integers(1, 6)),        # max_new_tokens
                     draw(st.integers(0, 10)),       # arrival tick
                     draw(st.integers(0, _VOCAB - 1))))  # last prompt token
    use_eos = draw(st.booleans())
    decode_chunk = draw(st.integers(1, 4))           # chunked ticks too
    return n_slots, reqs, use_eos, decode_chunk


def _expected_tokens(last, max_new, eos_id):
    toks = [(last + 1 + i) % _VOCAB for i in range(max_new)]
    if eos_id is not None and eos_id in toks:
        toks = toks[: toks.index(eos_id) + 1]
    return toks


@_SMALL
@given(_traffic())
def test_engine_scheduler_invariants(traffic):
    n_slots, reqs, use_eos, decode_chunk = traffic
    eos_id = 3 if use_eos else None
    engine = ServeEngine(FakeBackend(), n_slots, max_seq=16, eos_id=eos_id,
                         decode_chunk=decode_chunk)
    rids = []
    for plen, max_new, arrival, last in reqs:
        prompt = np.full(plen, last, np.int32)  # only the last token matters
        rids.append((engine.submit(prompt, max_new, arrival=arrival),
                     last, max_new))

    guard = 0
    while engine.queue or engine.sched.n_active:
        engine.step()
        guard += 1
        assert guard < 500, "engine failed to drain"
        # Pool partition invariant: free ∪ occupied, no overlap, no dupes.
        free = engine.sched._free
        occupied = set(engine.sched.owner)
        assert not set(free) & occupied
        assert len(free) == len(set(free))
        assert len(free) + len(occupied) == n_slots
        # No request owns two slots.
        owners = list(engine.sched.owner.values())
        assert len(owners) == len(set(owners))

    # Every admitted request retired exactly once, with the exact stream.
    assert engine.stats["admitted"] == engine.stats["retired"] == len(reqs)
    assert set(engine.sched.retired.values()) <= {1}
    for rid, last, max_new in rids:
        assert engine.finished[rid] == _expected_tokens(last, max_new, eos_id)
    # Drained pool is fully reset (every retirement flushed its slot).
    assert (engine.pool == 0).all()


@_SMALL
@given(st.integers(2, 6), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_slot_reset_is_bitwise_fresh(n_slots, n_reset, seed):
    """slot_reset on a randomly filled real cache (SWA ring + mamba + rwkv
    families) restores exactly the fresh-init rows, and touches no others."""
    from repro.configs import get_config
    from repro.models.model import cache_slot_reset, init_decode_cache

    cfg = get_config("jamba-v0.1-52b", smoke=True)
    fresh = init_decode_cache(cfg, n_slots, 6)
    key = jax.random.PRNGKey(seed)
    filled = jax.tree.map(
        lambda leaf: jax.random.normal(key, leaf.shape).astype(leaf.dtype),
        fresh)
    slots = jax.random.permutation(key, n_slots)[:n_reset]
    reset = cache_slot_reset(cfg, filled, slots)
    kept = np.setdiff1d(np.arange(n_slots), np.asarray(slots))
    for got, want, old in zip(jax.tree.leaves(reset), jax.tree.leaves(fresh),
                              jax.tree.leaves(filled)):
        got, want, old = (np.asarray(x) for x in (got, want, old))
        # jamba has no prologue, so every leaf is a scanned-period cache
        # with a leading n_periods axis — batch is axis 1.
        take = lambda arr, idx: np.take(arr, idx, axis=1)
        np.testing.assert_array_equal(take(got, np.asarray(slots)),
                                      take(want, np.asarray(slots)))
        np.testing.assert_array_equal(take(got, kept), take(old, kept))
