"""Paged decode-cache pool + prefix cache: bitwise parity and page hygiene.

The acceptance bar of the paging subsystem (launch/paging.py, DESIGN.md
§13): the paged engine — page-table gather → the *same* compiled decode
step as the contiguous engine → page commit — must emit token streams
bitwise identical to the contiguous slot pool on the same trace, across
cache families (SWA ring + global attention, mamba hybrid, rwkv), heads
(dense and fused sketch), and sampling (greedy and seeded), while the
prefix cache actually skips repeated prompts' prefills and COW actually
forks shared pages on first divergent write.  Page hygiene is pinned at
the device level: the reserved zero page reads zero after arbitrary
traffic, and inserting one slot's pages leaves every other page bitwise
frozen.  Host-side allocator/refcount invariants live in
tests/test_paging_properties.py (hypothesis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head
from repro.launch.engine import make_engine
from repro.models.model import (init_decode_cache, init_model,
                                init_paged_cache, paged_gather_cache,
                                paged_insert_cache)

_ARCHS = ["gemma2-27b", "jamba-v0.1-52b", "rwkv6-1.6b"]

_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)


@pytest.fixture(scope="module", params=_ARCHS)
def served(request):
    cfg = get_config(request.param, smoke=True)
    return cfg, init_model(jax.random.PRNGKey(0), cfg)


def _sketch_head(cfg):
    kp, ka, kj, kf = jax.random.split(jax.random.PRNGKey(42), 4)
    kparams = {
        "points": jax.random.normal(kp, (128, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(kj, (cfg.d_model, _HEAD_CFG.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    return SketchHead(cfg=_HEAD_CFG, backend="fused",
                      params=freeze_head(kf, kparams, _HEAD_CFG))


def _serve_both(params, cfg, reqs, *, head=None, sampler=None, n_slots=4,
                max_seq=32, page_size=4):
    """The same trace through the contiguous and the paged engine; returns
    ``(outputs_contiguous, outputs_paged, engine_paged)``."""
    outs = []
    engines = []
    for paged in (False, True):
        engine = make_engine(params, cfg, n_slots=n_slots, max_seq=max_seq,
                             head=head, sampler=sampler, paged=paged,
                             page_size=page_size)
        for rid, (prompt, gen, arrival) in enumerate(reqs):
            engine.submit(prompt, gen, arrival=arrival, rid=rid)
        outs.append(engine.run())
        engines.append(engine)
    return outs[0], outs[1], engines[1]


def _unique_reqs(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, rng.integers(4, 12),
                          dtype=np.int32),
             int(rng.integers(2, 7)), i // 2) for i in range(n)]


def _zipf_reqs(cfg, n=12, seed=1):
    """Repeated-prompt mix: 4 base prompts reused across the stream."""
    rng = np.random.default_rng(seed)
    base = [rng.integers(1, cfg.vocab_size, plen, dtype=np.int32)
            for plen in (5, 9, 5, 13)]
    return [(base[int(rng.integers(0, len(base)))],
             int(rng.integers(2, 7)), i // 3) for i in range(n)]


# --------------------------------------------------------------------------
# bitwise parity
# --------------------------------------------------------------------------

def test_paged_matches_contiguous_seeded_unique_prompts(served):
    """Unique prompts (no prefix hits), seeded sampling: the gathered page
    view must be byte-identical to the contiguous cache, so the streams
    and the key chain replay bitwise."""
    cfg, params = served
    contiguous, paged, engine = _serve_both(
        params, cfg, _unique_reqs(cfg),
        sampler=Sampler(temperature=1.0, top_k=8, seed=7))
    assert contiguous == paged
    assert engine.stats["prefix_hits"] == 0


def test_paged_matches_contiguous_seeded_repeated_prompts(served):
    """Zipf-style repeats, seeded: prefix hits replay the stored first
    logits and shared pages; the streams still match the contiguous
    engine's bitwise, and prefills actually drop."""
    cfg, params = served
    contiguous, paged, engine = _serve_both(
        params, cfg, _zipf_reqs(cfg),
        sampler=Sampler(temperature=1.0, seed=3))
    assert contiguous == paged
    assert engine.stats["prefix_hits"] > 0


def test_paged_prefix_cache_skips_prefills_greedy(served):
    """Greedy on the repeated-prompt mix: hit rate is real, the paged run
    prefills strictly less, and (for attention families) first divergent
    decode writes triggered COW page copies."""
    cfg, params = served
    reqs = _zipf_reqs(cfg)
    outs = {}
    stats = {}
    for paged in (False, True):
        engine = make_engine(params, cfg, n_slots=4, max_seq=32,
                             paged=paged, page_size=4)
        for rid, (p, g, a) in enumerate(reqs):
            engine.submit(p, g, arrival=a, rid=rid)
        outs[paged] = engine.run()
        stats[paged] = dict(engine.stats)
    assert outs[False] == outs[True]
    assert stats[True]["prefix_hits"] > 0
    assert stats[True]["prefill_batches"] < stats[False]["prefill_batches"]
    if any(k not in ("mamba", "rwkv", "xattn") for k in cfg.pattern):
        # Shared prefix pages must be copied before the first divergent
        # decode write lands (recurrent-only archs have no paged arena).
        assert stats[True]["cow_copies"] > 0


def test_paged_matches_contiguous_sketch_fused_head():
    cfg = get_config("gemma2-27b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    contiguous, paged, engine = _serve_both(
        params, cfg, _zipf_reqs(cfg), head=_sketch_head(cfg),
        sampler=Sampler(temperature=1.0, seed=11))
    assert contiguous == paged
    assert engine.stats["prefix_hits"] > 0


def test_dedupe_identical_prompts_in_one_admission_batch(served):
    """Same-batch duplicate prompts bulk-prefill once on the *non-paged*
    path too, and every copy still gets the right stream (greedy: all
    duplicates emit identical tokens)."""
    cfg, params = served
    rng = np.random.default_rng(5)
    shared = rng.integers(1, cfg.vocab_size, 6, dtype=np.int32)
    other = rng.integers(1, cfg.vocab_size, 6, dtype=np.int32)
    engine = make_engine(params, cfg, n_slots=4, max_seq=16)
    rids = [engine.submit(p, 4, arrival=0)
            for p in (shared, shared, other, shared)]
    out = engine.run()
    assert engine.stats["dedup_saved"] == 2
    assert engine.stats["prefill_batches"] == 1
    assert out[rids[0]] == out[rids[1]] == out[rids[3]]
    solo = make_engine(params, cfg, n_slots=4, max_seq=16)
    rid = solo.submit(shared, 4)
    assert solo.run()[rid] == out[rids[0]]


# --------------------------------------------------------------------------
# page hygiene (device level)
# --------------------------------------------------------------------------

def test_zero_page_reads_zero_after_traffic():
    """After a full serving run — allocs, COW copies, recycled dirty pages
    — a gather through an all-zero (unmapped) page table still reads
    exactly zeros: the reserved page 0 was never written."""
    cfg = get_config("gemma2-27b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = make_engine(params, cfg, n_slots=4, max_seq=32, paged=True,
                         page_size=4)
    for rid, (p, g, a) in enumerate(_zipf_reqs(cfg)):
        engine.submit(p, g, arrival=a, rid=rid)
    engine.run()
    unmapped = jnp.zeros_like(jnp.asarray(engine.page_pool.table))
    view = paged_gather_cache(cfg, engine.pages, unmapped, engine.max_seq)
    for leaf in jax.tree_util.tree_leaves(view):
        assert not np.asarray(leaf).any()


def test_paged_insert_freezes_unrelated_pages():
    """Inserting one slot's prefilled rows touches exactly that slot's
    pages: every other page of the arena stays bitwise frozen."""
    cfg = get_config("gemma2-27b", smoke=True)
    page_size, num_pages, size = 4, 9, 8
    pages = init_paged_cache(cfg, num_pages, page_size)
    # Pre-stamp the whole arena with noise so "frozen" is a real check.
    pages = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(1), x.shape,
                                    x.dtype), pages)
    src = init_decode_cache(cfg, 1, size)
    src = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(2), x.shape,
                                    x.dtype), src)
    npp = size // page_size
    pt_rows = jnp.asarray([[1, 2]], jnp.int32)       # slot maps pages 1, 2
    before = jax.tree.map(lambda x: np.asarray(x).copy(), pages)
    after = paged_insert_cache(cfg, pages, src, pt_rows)
    changed = {1, 2}
    for b, a in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        b, a = np.asarray(b), np.asarray(a)
        # Page axis is 0 for prologue leaves, 1 for scanned-period leaves
        # (leading n_periods axis); both shapes carry num_pages there.
        axis = 0 if b.shape[0] == num_pages else 1
        for pid in range(num_pages):
            sl = (pid,) if axis == 0 else (slice(None), pid)
            if pid in changed:
                continue
            np.testing.assert_array_equal(a[sl], b[sl],
                                          err_msg=f"page {pid} mutated")


# --------------------------------------------------------------------------
# configuration errors + pool exhaustion
# --------------------------------------------------------------------------

def test_paged_excludes_megastep_and_spec_decode():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        make_engine(params, cfg, n_slots=2, max_seq=16, paged=True,
                    decode_chunk=4)
    with pytest.raises(ValueError, match="paged"):
        make_engine(params, cfg, n_slots=2, max_seq=16, paged=True,
                    spec_decode=2)


def test_page_pool_exhaustion_raises():
    """A pool sized below the working set fails loudly (after LRU eviction
    cannot reclaim anything) instead of corrupting shared pages."""
    cfg = get_config("gemma2-27b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = make_engine(params, cfg, n_slots=2, max_seq=16, paged=True,
                        page_size=4, num_pages=3)
    rng = np.random.default_rng(9)
    for i in range(2):
        engine.submit(rng.integers(1, cfg.vocab_size, 8, dtype=np.int32),
                      4, rid=i)
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        engine.run()
