"""Property-based invariants for speculative self-decode (DESIGN.md §11).

Two families, under random traffic (draft lengths × generation budgets ×
sampler seeds × draft-head seeds):

* **Token accounting** — the emitted stream is exactly what the megasteps
  committed: every verify commits at least one token (the verify pass
  itself always yields the next dense token) and at most one *bonus* token
  beyond the accepted drafts, so

      accepted  <=  emitted  <=  accepted + verify_calls
      accepted  <=  drafted  ==  sum(draft block sizes)

  with the emitted stream still bitwise the dense stream — draft quality
  (here: a random head, i.e. near-zero acceptance) moves only the stats.

* **Self-verification fixed point** — when the draft head *is* the dense
  head, every draft is its own verify draw, so the acceptance rate is
  exactly 1.0: ``accepted == drafted`` for any K, greedy or seeded.

Marked slow: tier-1 (-m "not slow") stays fast.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests are skipped without hypothesis")
from hypothesis import given, settings, strategies as st

import jax

from repro.api import LM, Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head

pytestmark = pytest.mark.slow

# Few examples, no deadline: each example is a real (smoke-scale) serving
# run; draft lengths are capped so the jitted-megastep memo cache bounds
# compiles across examples.
_SETTINGS = settings(max_examples=10, deadline=None)
_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)


@pytest.fixture(scope="module")
def served():
    from repro.models.model import init_model

    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _random_head(cfg, seed):
    kp, ka, kj, kf = jax.random.split(jax.random.PRNGKey(seed), 4)
    kparams = {
        "points": jax.random.normal(kp, (64, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (64, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(kj, (cfg.d_model, _HEAD_CFG.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    return SketchHead(cfg=_HEAD_CFG, backend="ref",
                      params=freeze_head(kf, kparams, _HEAD_CFG))


def _sampler(seed):
    # seed == 0 → greedy; else a seeded categorical chain
    return Sampler() if seed == 0 else Sampler(temperature=0.9, top_k=12,
                                               seed=seed)


@_SETTINGS
@given(gen_len=st.integers(2, 12), spec_k=st.integers(1, 4),
       head_seed=st.integers(0, 2 ** 16), sampler_seed=st.integers(0, 3))
def test_token_accounting(served, gen_len, spec_k, head_seed, sampler_seed):
    """accepted <= emitted <= accepted + verify_calls, accepted <= drafted,
    drafted == the sum of clamped draft blocks — and the stream is still
    bitwise dense."""
    cfg, params = served
    lm = LM(params, cfg, _random_head(cfg, head_seed))
    sampler = _sampler(sampler_seed)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    out, stats = lm.generate(prompts, gen_len, sampler=sampler,
                             spec_decode=spec_k, return_stats=True)
    base = np.asarray(LM(params, cfg).generate(prompts, gen_len,
                                               sampler=sampler))
    np.testing.assert_array_equal(np.asarray(out), base)

    b = prompts.shape[0]
    emitted = b * (gen_len - 1)          # first token comes from prefill
    accepted = stats["accepted_draft_tokens"]
    drafted = stats["draft_tokens"]
    verifies = stats["verify_calls"]
    assert 0 <= accepted <= drafted
    assert drafted == b * stats["decode_steps"]   # every draft is a step
    assert stats["decode_steps"] <= verifies * spec_k
    # each verify commits >= 1 and <= spec_k tokens per row (lockstep):
    assert accepted <= emitted <= accepted + b * verifies
    if gen_len > 1:
        assert verifies >= 1


@_SETTINGS
@given(gen_len=st.integers(2, 12), spec_k=st.integers(1, 4),
       sampler_seed=st.integers(0, 3))
def test_dense_draft_accepts_everything(served, gen_len, spec_k,
                                        sampler_seed):
    """When the draft head IS the dense head, every draft is its own verify
    draw: acceptance rate is exactly 1.0 and every megastep commits its
    full block."""
    cfg, params = served
    lm = LM(params, cfg)                 # DenseHead drafts AND verifies
    sampler = _sampler(sampler_seed)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    out, stats = lm.generate(prompts, gen_len, sampler=sampler,
                             spec_decode=spec_k, return_stats=True)
    assert stats["accepted_draft_tokens"] == stats["draft_tokens"]
    base = np.asarray(lm.generate(prompts, gen_len, sampler=sampler))
    np.testing.assert_array_equal(np.asarray(out), base)
