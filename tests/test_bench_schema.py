"""The benchmarks.schema CLI and validators (EXPERIMENTS.md §Bench schema).

The committed BENCH_*.json artifacts must validate against the current
schema version (stale artifacts fail here, not in CI archaeology), and the
CLI must check *every* path before exiting: the regression is the
multi-file invalid case — an early invalid file used to raise and skip the
rest, so CI saw one failure per run instead of the full damage report.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks.schema import (SCHEMA_VERSION, main, validate_engine_record,
                               validate_serve_record)

_ROOT = Path(__file__).resolve().parents[1]
_ENGINE = _ROOT / "BENCH_engine.json"
_SERVE = _ROOT / "BENCH_sketch_serve.json"


def test_committed_artifacts_validate(capsys):
    """The checked-in artifacts match the current schema (v6: heavy_tail
    paged-vs-contiguous section with latency percentiles + paging
    counters)."""
    assert main([str(_ENGINE), str(_SERVE)]) == 0
    out = capsys.readouterr().out
    assert out.count(f"valid (schema v{SCHEMA_VERSION})") == 2


def test_engine_artifact_heavy_tail_is_real_measurement():
    """The committed heavy-tail section demonstrates the paging win, not a
    placeholder: Zipf reuse drove the hit rate past 0.5, the paged run
    prefilled strictly less than the contiguous one at equal (bitwise)
    output, and the latency percentiles are ordered."""
    ht = json.loads(_ENGINE.read_text())["heavy_tail"]
    assert ht["requests"] >= 1000
    assert ht["outputs_match"] is True
    assert ht["prefix_hit_rate"] > 0.5
    assert ht["prefill_batches"] < ht["prefill_batches_contiguous"]
    assert ht["pages_in_use_peak"] > 0
    assert 0 < ht["latency_ticks_p50"] <= ht["latency_ticks_p99"]
    for mode in ("contiguous", "paged"):
        assert ht[mode]["tokens_per_s_per_slot"] > 0


def test_heavy_tail_validation_catches_divergence_and_regression(tmp_path):
    """Schema v6 gates: a heavy_tail section claiming diverged outputs or
    more paged prefills than contiguous is rejected."""
    record = json.loads(_ENGINE.read_text())
    record["heavy_tail"]["outputs_match"] = False
    with pytest.raises(ValueError, match="outputs_match"):
        validate_engine_record(record)
    record = json.loads(_ENGINE.read_text())
    record["heavy_tail"]["prefill_batches"] = (
        record["heavy_tail"]["prefill_batches_contiguous"] + 1)
    with pytest.raises(ValueError, match="prefill_batches"):
        validate_engine_record(record)
    record = json.loads(_ENGINE.read_text())
    record["heavy_tail"]["prefix_hit_rate"] = 1.2
    with pytest.raises(ValueError, match="prefix_hit_rate"):
        validate_engine_record(record)
    record = json.loads(_ENGINE.read_text())
    del record["heavy_tail"]
    with pytest.raises(ValueError, match="heavy_tail"):
        validate_engine_record(record)


def test_engine_artifact_has_nonzero_acceptance():
    """The v4 spec sweep is real measurement, not a zeroed placeholder: the
    distilled draft head must beat the ~1/V random-agreement floor."""
    record = json.loads(_ENGINE.read_text())
    for k, run in record["spec_decode"].items():
        assert run["acceptance_rate"] > 0, f"spec_decode[{k}] zero acceptance"
        assert run["accepted_tokens_per_verify"] > 0


def test_cli_validates_every_path_and_reports_all(tmp_path, capsys):
    """Multi-file invalid case: every path is checked, every failure is
    printed, and the exit code is non-zero — the first bad file must not
    mask the rest."""
    bad_missing = tmp_path / "bad_missing.json"
    record = json.loads(_ENGINE.read_text())
    del record["static"]
    bad_missing.write_text(json.dumps(record))
    bad_parse = tmp_path / "bad_parse.json"
    bad_parse.write_text("{not json")
    good = tmp_path / "good.json"
    good.write_text(_SERVE.read_text())

    rc = main([str(bad_missing), str(good), str(bad_parse)])
    out = capsys.readouterr().out
    assert rc == 1
    assert f"{bad_missing}: INVALID" in out and "static" in out
    assert f"{bad_parse}: INVALID" in out
    assert f"{good}: valid" in out            # later files still validated
    assert "2 of 3 artifacts failed" in out


def test_cli_exit_codes_subprocess(tmp_path):
    """python -m benchmarks.schema exits 0 on valid input, 1 on any invalid
    path — the contract the CI bench-smoke job scripts against."""
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    run = lambda *paths: subprocess.run(
        [sys.executable, "-m", "benchmarks.schema", *paths],
        cwd=_ROOT, capture_output=True, text=True)
    ok = run(str(_ENGINE), str(_SERVE))
    assert ok.returncode == 0, ok.stdout + ok.stderr
    fail = run(str(_ENGINE), str(bad))
    assert fail.returncode == 1
    assert "INVALID" in fail.stdout
    assert f"{_ENGINE}: valid" in fail.stdout


def test_spec_run_range_checks():
    """Out-of-range spec stats are rejected, not just missing fields."""
    record = json.loads(_ENGINE.read_text())
    k = next(iter(record["spec_decode"]))
    record["spec_decode"][k]["acceptance_rate"] = 1.5
    with pytest.raises(ValueError, match="acceptance_rate"):
        validate_engine_record(record)

    serve = json.loads(_SERVE.read_text())
    serve["spec_decode"]["acceptance_rate"] = -0.1
    with pytest.raises(ValueError, match="acceptance_rate"):
        validate_serve_record(serve)


def test_quant_curve_required_and_checked():
    """Schema v5: the serve record must carry the full quant_curve and the
    dtype-aware bytes fields, with per-mode range checks."""
    serve = json.loads(_SERVE.read_text())
    missing = json.loads(_SERVE.read_text())
    del missing["quant_curve"]
    with pytest.raises(ValueError, match="quant_curve"):
        validate_serve_record(missing)
    for field in ("dense_bytes", "sketch_bytes", "bytes_ratio"):
        broken = json.loads(_SERVE.read_text())
        del broken[field]
        with pytest.raises(ValueError, match=field):
            validate_serve_record(broken)
    partial = json.loads(_SERVE.read_text())
    del partial["quant_curve"]["int4"]
    with pytest.raises(ValueError, match="int4"):
        validate_serve_record(partial)
    serve["quant_curve"]["int8"]["top1_agreement"] = 1.2
    with pytest.raises(ValueError, match="top1_agreement"):
        validate_serve_record(serve)


def test_serve_artifact_quant_curve_monotone():
    """The committed curve is real measurement: the f32 row is exact,
    accuracy degrades with fewer bits while the storage ratio climbs past
    the acceptance floors (≥3.9× int8, ≥7.8× int4 at bench scale)."""
    curve = json.loads(_SERVE.read_text())["quant_curve"]
    assert curve["f32"]["logit_mae"] == 0.0
    assert curve["f32"]["top1_agreement"] == 1.0
    assert curve["int8"]["logit_mae"] <= curve["int4"]["logit_mae"]
    assert curve["int8"]["top1_agreement"] >= curve["int4"]["top1_agreement"]
    assert curve["int8"]["bytes_ratio"] >= 3.9
    assert curve["int4"]["bytes_ratio"] >= 7.8


def test_version_mismatch_rejected():
    """An artifact from an older schema fails with a regenerate hint."""
    record = json.loads(_SERVE.read_text())
    record["schema_version"] = SCHEMA_VERSION - 1
    with pytest.raises(ValueError, match="schema_version"):
        validate_serve_record(record)
