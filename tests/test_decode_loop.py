"""Chunked-decode parity: on-device megasteps vs the per-token host loop.

The megastep (launch/decode_loop.py, DESIGN.md §10) fuses K decode steps,
the Sampler, and EOS retirement into one ``lax.scan`` dispatch.  Its whole
contract is that chunking is *invisible* in the tokens: greedy and seeded
streams must be bitwise-equal across K ∈ {1, 4, 16} — K=1 being the
pre-megastep host loop — for the dense and fused-sketch heads, through both
the static ``generate`` path and the continuous-batching engine, including
EOS firing mid-chunk.  Donation is load-bearing here too: every one of
these runs exercises the donated decode/megastep/slot-op paths, so a
use-after-donate anywhere in the serving loop fails loudly (jax deletes
donated buffers on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LM, Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head
from repro.launch.serve import generate

_CHUNKS = [1, 4, 16]
_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)
_SAMPLERS = {
    "greedy": Sampler(),
    "seeded": Sampler(temperature=0.9, top_k=12, seed=7),
}


@pytest.fixture(scope="module")
def served():
    from repro.models.model import init_model

    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    kp, ka, kj, kf = jax.random.split(jax.random.PRNGKey(42), 4)
    kparams = {
        "points": jax.random.normal(kp, (128, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(kj, (cfg.d_model, _HEAD_CFG.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    head = SketchHead(cfg=_HEAD_CFG, backend="fused",
                      params=freeze_head(kf, kparams, _HEAD_CFG))
    return cfg, params, head


def _lm(served, kind):
    cfg, params, head = served
    return LM(params, cfg) if kind == "dense" else LM(params, cfg, head)


def _prompts(cfg, b=3, p=5):
    return jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                              cfg.vocab_size)


# --------------------------------------------------------------------------
# the parity grid: K × head × sampler × {generate, engine}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", sorted(_SAMPLERS))
@pytest.mark.parametrize("kind", ["dense", "sketch-fused"])
def test_generate_bitwise_equal_across_chunks(served, kind, sampler):
    """Static generate: megastep streams == host-loop streams, bitwise."""
    lm = _lm(served, kind)
    prompts = _prompts(lm.cfg)
    outs = [np.asarray(lm.generate(prompts, 9, sampler=_SAMPLERS[sampler],
                                   decode_chunk=k)) for k in _CHUNKS]
    for k, out in zip(_CHUNKS[1:], outs[1:]):
        np.testing.assert_array_equal(
            out, outs[0], err_msg=f"decode_chunk={k} diverged from the "
            f"host loop ({kind}, {sampler})")


@pytest.mark.parametrize("sampler", sorted(_SAMPLERS))
@pytest.mark.parametrize("kind", ["dense", "sketch-fused"])
def test_engine_bitwise_equal_across_chunks(served, kind, sampler):
    """Engine: chunked ticks emit exactly the per-token-tick streams
    (synchronized arrivals keep the admission order — and so the seeded
    key chain — identical across K)."""
    lm = _lm(served, kind)
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    reqs = [(np.asarray(prompts[i]), g) for i in range(b)]
    base = lm.serve(reqs, n_slots=b, sampler=_SAMPLERS[sampler])
    for k in _CHUNKS[1:]:
        got = lm.serve(reqs, n_slots=b, sampler=_SAMPLERS[sampler],
                       decode_chunk=k)
        assert got == base, (f"engine decode_chunk={k} diverged "
                             f"({kind}, {sampler})")


def test_engine_chunked_matches_static_generate(served):
    """Cross-path: the chunked engine reproduces the host-loop static
    generate (the tightest end-to-end invariant — scheduler, megastep, and
    slot ops all in the loop)."""
    lm = _lm(served, "sketch-fused")
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    expected = np.asarray(lm.generate(prompts, g))
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b, decode_chunk=4)
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      expected[i, p:])


def test_engine_chunked_staggered_matches_solo_generate(served):
    """Slot recycling under chunked ticks: every request of a staggered,
    mixed-length stream still emits exactly its solo-generate stream."""
    lm = _lm(served, "dense")
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, lm.cfg.vocab_size, 4 + (i % 3), dtype=np.int32),
             3 + 2 * (i % 3), i) for i in range(5)]
    finished = lm.serve(reqs, n_slots=2, decode_chunk=4)
    for rid, (prompt, gen, _) in enumerate(reqs):
        solo = np.asarray(lm.generate(prompt[None], gen))
        np.testing.assert_array_equal(np.asarray(finished[rid]),
                                      solo[0, len(prompt):])


# --------------------------------------------------------------------------
# EOS mid-chunk
# --------------------------------------------------------------------------

def test_eos_mid_chunk_generate(served):
    """An EOS inside a chunk retires the row in-scan: the stream matches
    the host loop's (pad tail included) at every K."""
    lm = _lm(served, "dense")
    prompts = _prompts(lm.cfg)
    plain = np.asarray(lm.generate(prompts, 9))
    eos = int(plain[0, 5 + 3])           # emitted mid-way through chunk 1
    base = np.asarray(lm.generate(prompts, 9, eos_id=eos, pad_id=0))
    assert (base[0] == 0).any()          # the EOS actually fired
    for k in (4, 16):
        got = np.asarray(lm.generate(prompts, 9, eos_id=eos, pad_id=0,
                                     decode_chunk=k))
        np.testing.assert_array_equal(got, base)


def test_eos_mid_chunk_engine(served):
    """Engine: mid-chunk EOS retires the request with exactly the K=1
    stream (trailing in-chunk block entries are discarded, the slot resets
    and is reusable)."""
    lm = _lm(served, "dense")
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    plain = np.asarray(lm.generate(prompts, g))
    eos = int(plain[0, p + 3])
    reqs = [(np.asarray(prompts[i]), g) for i in range(b)]
    base = lm.serve(reqs, n_slots=b, eos_id=eos)
    assert any(s[-1] == eos and len(s) < g for s in base.values())
    for k in (4, 16):
        engine = lm.engine(n_slots=b, max_seq=p + g, eos_id=eos,
                           decode_chunk=k)
        rids = [engine.submit(pr, mx) for pr, mx in reqs]
        got = engine.run()
        assert {r: got[r] for r in rids} == base
        assert engine.stats["admitted"] == engine.stats["retired"] == b
        assert engine.sched.n_free == b   # every slot recycled


def test_eos_with_queued_requests_chunked(served):
    """Mid-chunk EOS while requests queue: a K=1 engine refills the freed
    slot next tick, a chunked one at the chunk boundary.  Greedy streams
    are still K-invariant per request (each depends only on its own
    prompt), and seeded runs are reproducible per (seed, K) — the across-K
    seeded caveat documented in docs/serving.md."""
    lm = _lm(served, "dense")
    p, g = 5, 9
    prompts = _prompts(lm.cfg, 4, p)
    eos = int(np.asarray(lm.generate(prompts, g))[0, p + 3])
    reqs = [(np.asarray(prompts[i % 4]), g) for i in range(6)]  # 6 > slots

    base = lm.serve(reqs, n_slots=2, eos_id=eos)
    for k in (4, 16):
        got = lm.serve(reqs, n_slots=2, eos_id=eos, decode_chunk=k)
        assert got == base, f"greedy streams must be K-invariant (K={k})"

    seeded = Sampler(temperature=0.9, seed=11)
    a = lm.serve(reqs, n_slots=2, eos_id=eos, sampler=seeded, decode_chunk=4)
    b = lm.serve(reqs, n_slots=2, eos_id=eos, sampler=seeded, decode_chunk=4)
    assert a == b, "seeded chunked runs must reproduce per (seed, K)"


# --------------------------------------------------------------------------
# donation: the cache is consumed, and the loop never reuses it
# --------------------------------------------------------------------------

def test_jitted_serve_fns_decode_chunk_knob(served):
    """The public decode_chunk knob on jitted_serve_fns: the returned
    struct unpacks as the legacy 4-tuple, shares the (cfg, head, mesh)
    compile cache across sampler specs (a new sampler must not recompile
    the model steps), and carries the memoized megastep."""
    from repro.api.heads import DenseHead
    from repro.launch.decode_loop import jitted_megastep
    from repro.launch.steps import jitted_serve_fns

    cfg, _, _ = served
    base = jitted_serve_fns(cfg)
    assert base is jitted_serve_fns(cfg)          # stable identity at K=1
    a = jitted_serve_fns(cfg, sampler=Sampler(), decode_chunk=8)
    b = jitted_serve_fns(cfg, sampler=Sampler(temperature=0.5, seed=2),
                         decode_chunk=8)
    prefill, decode, insert, reset = a            # legacy unpacking
    assert (decode is base.decode) and (b.decode is base.decode)
    assert a.megastep is jitted_megastep(cfg, DenseHead(), Sampler(), 8,
                                         masked=True)
    assert b.megastep is not a.megastep           # sampler is in its key
    with pytest.raises(ValueError, match="sampler"):
        jitted_serve_fns(cfg, decode_chunk=8)
    with pytest.raises(ValueError, match="decode_chunk"):
        jitted_serve_fns(cfg, decode_chunk=0)


def test_decode_and_slot_ops_donate_cache(served):
    """decode/insert/reset/megastep donate their cache argument: the
    passed-in buffers are deleted (jax implements donation on CPU), so the
    per-token full-cache copy is gone."""
    from repro.launch.decode_loop import jitted_megastep
    from repro.launch.steps import jitted_serve_fns
    from repro.models.model import init_decode_cache

    cfg, params, _ = served
    prefill, decode, insert, reset = jitted_serve_fns(cfg)
    deleted = lambda c: all(leaf.is_deleted() for leaf in jax.tree.leaves(c))

    logits, cache = prefill(params, _prompts(cfg, 2, 4),
                            cache=init_decode_cache(cfg, 2, 8))
    old = cache
    _, cache = decode(params, cache, jnp.ones((2, 1), jnp.int32),
                      jnp.asarray(4, jnp.int32))
    assert deleted(old)

    old = cache
    cache = reset(cache, jnp.asarray([0, 1]))
    assert deleted(old)

    fn = jitted_megastep(cfg, LM(params, cfg).head, Sampler(), 4,
                         masked=True)
    old = cache
    _, cache, *_ = fn(params, cache, jnp.zeros(2, jnp.int32),
                      jnp.full(2, 4, jnp.int32), Sampler().init_key(),
                      active=jnp.asarray([True, True]))
    assert deleted(old)


def test_engine_survives_donation_end_to_end(served):
    """A full chunked engine run over recycled slots: any use-after-donate
    in admit → megastep → retire → reset would raise on CPU (donated
    buffers are deleted), so completion + correct streams is the proof."""
    lm = _lm(served, "sketch-fused")
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, lm.cfg.vocab_size, 5, dtype=np.int32),
             4 + (i % 4), i % 3) for i in range(6)]
    finished = lm.serve(reqs, n_slots=2, decode_chunk=4)
    assert sorted(finished) == list(range(6))
    assert all(len(finished[i]) == reqs[i][1] for i in range(6))
