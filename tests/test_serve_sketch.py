"""Serving with the sketched LM head: fused path parity, bulk prefill, and
an end-to-end generate smoke with the sketch head enabled."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sketch_lm_head import (apply_head, freeze_head, load_head,
                                       save_head)
from repro.launch.serve import generate
from repro.launch.steps import prefill_step, serve_step
from repro.models.config import SketchHeadConfig
from repro.models.model import forward, init_decode_cache, init_model


def _direct_head(key, d_model: int, vocab: int, cfg: SketchHeadConfig):
    """Direct-construction frozen head (distillation quality is covered by
    tests/test_system.py; these tests exercise the serving plumbing)."""
    kp, ka, kj, kf = jax.random.split(key, 4)
    kparams = {
        "points": jax.random.normal(kp, (128, cfg.proj_dim)),
        "alphas": jax.random.normal(ka, (128, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    return freeze_head(kf, kparams, cfg)


def test_apply_head_fused_matches_two_kernel():
    cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=2, proj_dim=16,
                           bandwidth=2.0)
    head = _direct_head(jax.random.PRNGKey(0), 48, 200, cfg)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (11, 48))
    two = apply_head(head, hidden, cfg, fused=False)
    fused = apply_head(head, hidden, cfg, fused=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                               rtol=1e-5, atol=1e-5)


def test_head_save_load_roundtrip(tmp_path):
    cfg = SketchHeadConfig(n_rows=16, n_buckets=8, k=1, proj_dim=8,
                           bandwidth=1.5)
    head = _direct_head(jax.random.PRNGKey(2), 24, 64, cfg)
    save_head(tmp_path / "head.npz", head, cfg)
    head2, cfg2 = load_head(tmp_path / "head.npz")
    assert cfg2 == cfg
    for k in head:
        np.testing.assert_array_equal(np.asarray(head[k]),
                                      np.asarray(head2[k]))


def test_serve_step_sketch_head_skips_dense_logits():
    """serve_step with a sketch head returns sketched (B, V) logits."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                                bandwidth=2.0)
    head = _direct_head(jax.random.PRNGKey(3), cfg.d_model, cfg.vocab_size,
                        head_cfg)
    cache = init_decode_cache(cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((), jnp.int32)
    logits, new_cache = serve_step(params, cache, tok, pos, cfg,
                                   sketch_head=head, sketch_cfg=head_cfg)
    assert logits.shape == (2, cfg.vocab_size)
    # The sketched logits come from the frozen head, not the dense unembed:
    # applying the head to the returned hidden reproduces them exactly.
    from repro.models.model import decode_step
    hidden, _ = decode_step(params, cache, tok, pos, cfg, return_hidden=True)
    np.testing.assert_allclose(
        np.asarray(apply_head(head, hidden, head_cfg, fused=True)),
        np.asarray(logits), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch,plen", [
    ("musicgen-large", 7),
    ("rwkv6-1.6b", 7),
    ("gemma2-27b", 4),      # SWA ring (smoke window=8): prompt < window
    ("gemma2-27b", 12),     # prompt > window — ring wraps during prefill
    ("mixtral-8x7b", 20),   # prompt >> window + MoE routing groups
])
def test_bulk_prefill_matches_cacheless_forward(arch, plen):
    """prefill_step with a cache must agree with the training-path forward
    on the last-position logits (the decode cache it fills is then trusted
    by every subsequent serve_step)."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, plen), 0,
                                 cfg.vocab_size)
    cache = init_decode_cache(cfg, 2, plen + 5)
    logits_bulk, new_cache = prefill_step(params, prompts, cfg, cache=cache)
    logits_fwd, _, _ = forward(params, prompts, cfg, remat=False)
    np.testing.assert_allclose(np.asarray(logits_bulk),
                               np.asarray(logits_fwd[:, -1]),
                               rtol=1e-4, atol=1e-4)
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_swa_decode_continues_from_bulk_prefill():
    """The ring cache rebuilt by a wrapping bulk prefill must support exact
    decode continuation: prefill(P tokens) + one decode step == the
    cacheless forward over P+1 tokens at the last position (gemma2 smoke:
    window=8 < P=12, softcap on)."""
    cfg = get_config("gemma2-27b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    p = 12
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, p), 0,
                                 cfg.vocab_size)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0,
                             cfg.vocab_size)
    truth, _, _ = forward(params, jnp.concatenate([prompts, nxt], axis=1),
                          cfg, remat=False)
    cache = init_decode_cache(cfg, 2, p + 4)
    _, cache = prefill_step(params, prompts, cfg, cache=cache)
    logits, _ = serve_step(params, cache, nxt, jnp.asarray(p, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(truth[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_mamba_bulk_prefill_state_survives_chunk_padding():
    """A chunk-padded bulk prefill (s > _SCAN_CHUNK, s % chunk != 0) must
    save the same SSM state as two unpadded passes — padded positions are
    state-identity, not spurious decay steps."""
    from repro.models.config import MambaConfig
    from repro.models.mamba import init_mamba, init_mamba_cache, mamba_block

    cfg = MambaConfig()
    d = 32
    params = init_mamba(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 300, d)) * 0.1
    c0 = init_mamba_cache(2, d, cfg)
    _, c_full = mamba_block(params, x, cfg, cache=c0)       # chunk=256, pad=212
    _, c_half = mamba_block(params, x[:, :150], cfg, cache=c0)   # no padding
    _, c_two = mamba_block(params, x[:, 150:], cfg, cache=c_half)
    np.testing.assert_allclose(np.asarray(c_full.ssm), np.asarray(c_two.ssm),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_full.conv), np.asarray(c_two.conv),
                               rtol=1e-5, atol=1e-5)


def test_long_cached_prefill_uses_chunked_attention():
    """Cached bulk prefill above the SWA chunk threshold (s > window +
    _KV_CHUNK) must match cacheless attention — via the online-softmax path
    that never materializes the (Sq, Sk) score rectangle."""
    from repro.models.attention import attention, init_cache
    from repro.models.config import AttentionConfig

    cfg = AttentionConfig(n_heads=2, n_kv_heads=2, head_dim=8, window=8)
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {name: jax.random.normal(k, (16, 16)) * 0.1
              for name, k in zip(("wq", "wk", "wv", "wo"), keys)}
    s = 1040  # > window + 1024
    x = jax.random.normal(keys[4], (1, s, 16)) * 0.5
    pos = jnp.arange(s)
    cache = init_cache(1, 8, cfg, dtype=jnp.float32)
    out_cached, _ = attention(params, x, pos, cfg, cache=cache,
                              cache_pos=jnp.zeros((), jnp.int32))
    out_free, _ = attention(params, x, pos, cfg)
    np.testing.assert_allclose(np.asarray(out_cached), np.asarray(out_free),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_serve_generate_with_sketch_head(fused):
    """End-to-end smoke: generate() decodes through the sketched head."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                                bandwidth=2.0)
    head = _direct_head(jax.random.PRNGKey(4), cfg.d_model, cfg.vocab_size,
                        head_cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 5), 0,
                                 cfg.vocab_size)
    out = generate(params, cfg, prompts, gen_len=4,
                   sketch_head_params=head, sketch_cfg=head_cfg, fused=fused)
    assert out.shape == (2, 9)
    assert out.dtype == jnp.int32
    assert int(out.max()) < cfg.vocab_size and int(out.min()) >= 0
    np.testing.assert_array_equal(np.asarray(out[:, :5]),
                                  np.asarray(prompts))


def test_sketch_and_dense_generate_agree_on_prompt_echo():
    """Fused and two-kernel sketch decodes produce identical tokens (the
    same head, bit-identical indices ⇒ same argmax)."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                                bandwidth=2.0)
    head = _direct_head(jax.random.PRNGKey(6), cfg.d_model, cfg.vocab_size,
                        head_cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(7), (2, 4), 0,
                                 cfg.vocab_size)
    a = generate(params, cfg, prompts, gen_len=3,
                 sketch_head_params=head, sketch_cfg=head_cfg, fused=True)
    b = generate(params, cfg, prompts, gen_len=3,
                 sketch_head_params=head, sketch_cfg=head_cfg, fused=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
