"""Quantized count-array storage (DESIGN.md §12): numerics edge cases,
int4 packing, pallas-vs-ref parity grids, the quantize/save/load/serve
plumbing, and the satellite bugfixes (apply_head backend conflict, robust
config coercion, versioned archives)."""

import dataclasses
import zipfile
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch_lm_head import (HEAD_FORMAT_VERSION, apply_head,
                                       coerce_config, dequantize_head,
                                       head_costs, load_head_full,
                                       load_head_meta, quantize_counts,
                                       quantize_head, save_head)
from repro.kernels.common import pack_int4_rows, unpack_int4_rows
from repro.kernels.fused_decode.ops import fused_decode_logits
from repro.kernels.sketch_head.ops import sketch_head_logits
from repro.models.config import SketchHeadConfig
from repro.optim.compress import quantize_symmetric

DATA = Path(__file__).parent / "data"


def _head(key, d_model, vocab, cfg):
    """Direct-construction frozen head (the bench/test pattern)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "proj": jax.random.normal(k1, (d_model, cfg.proj_dim)),
        "w": jax.random.normal(k2, (cfg.n_rows, cfg.k, cfg.proj_dim)),
        "b": jax.random.uniform(k3, (cfg.n_rows, cfg.k)) * cfg.bandwidth,
        "array": jax.random.normal(k4, (cfg.n_rows, cfg.n_buckets, vocab))
        * 3.0,
    }


# ---------------------------------------------------------------- numerics

def test_quantize_symmetric_all_zero_rows_finite():
    # The scale guard must keep all-zero (and constant-zero) rows finite:
    # scale 1/qmax, q == 0, dequant == 0 — no inf/nan anywhere.
    x = jnp.zeros((4, 3, 16))
    for bits in (8, 4):
        q, scale = quantize_symmetric(x, bits=bits, axis=-1)
        assert bool(jnp.all(jnp.isfinite(scale)))
        assert bool(jnp.all(scale > 0))
        assert bool(jnp.all(q == 0))
        assert bool(jnp.all(jnp.isfinite(q.astype(jnp.float32)
                                         * scale[:, :, None])))


def test_quantize_symmetric_constant_rows():
    # A constant row quantizes to ±qmax exactly and dequantizes exactly.
    x = jnp.full((2, 2, 8), -1.5)
    q, scale = quantize_symmetric(x, bits=8, axis=-1)
    np.testing.assert_array_equal(np.asarray(q), -127)
    deq = q.astype(jnp.float32) * scale[:, :, None]
    np.testing.assert_allclose(np.asarray(deq), -1.5, rtol=1e-6)


def test_quantize_symmetric_mixed_zero_rows():
    # Zero rows coexisting with live rows: per-row scales keep them apart.
    x = jnp.concatenate([jnp.zeros((1, 2, 8)),
                         jnp.ones((1, 2, 8)) * 5.0], axis=0)
    q, scale = quantize_symmetric(x, bits=8, axis=-1)
    assert bool(jnp.all(jnp.isfinite(scale)))
    deq = q.astype(jnp.float32) * scale[:, :, None]
    np.testing.assert_allclose(np.asarray(deq[0]), 0.0)
    np.testing.assert_allclose(np.asarray(deq[1]), 5.0, rtol=1e-6)


def test_int8_round_trip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 5, 64)) * 2.0
    q, scale = quantize_symmetric(x, bits=8, axis=-1)
    deq = q.astype(jnp.float32) * scale[:, :, None]
    # Max error of symmetric rounding is scale/2 per element.
    assert bool(jnp.all(jnp.abs(deq - x) <= scale[:, :, None] * 0.5 + 1e-6))


@pytest.mark.parametrize("n_rows", [1, 2, 5, 6])
@pytest.mark.parametrize("v", [7, 16, 33])  # odd V must round-trip exactly
def test_int4_pack_unpack_round_trip(n_rows, v):
    key = jax.random.PRNGKey(n_rows * 100 + v)
    q = jax.random.randint(key, (n_rows, 3, v), -7, 8).astype(jnp.int8)
    packed = pack_int4_rows(q)
    assert packed.shape == ((n_rows + 1) // 2, 3, v)
    assert packed.dtype == jnp.int8
    out = unpack_int4_rows(packed, n_rows)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q))


def test_quantize_counts_int4_values_in_range():
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 4, 32)) * 10
    q, scale = quantize_symmetric(x, bits=4, axis=-1)
    assert int(jnp.max(q)) <= 7 and int(jnp.min(q)) >= -7
    store, scale2 = quantize_counts(x, "int4")
    assert store.shape == (3, 4, 32)      # rows packed pairwise, odd L pads
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(scale2))


# ------------------------------------------------- kernel parity grids

@pytest.mark.parametrize("quant", ["int8", "int4"])
@pytest.mark.parametrize("l,r,v", [(5, 5, 130), (6, 12, 100), (16, 8, 256)])
def test_sketch_head_quant_pallas_vs_ref(quant, l, r, v):
    key = jax.random.PRNGKey(l * r + v)
    sketch = jax.random.normal(key, (l, r, v)) * 3
    idx = jax.random.randint(key, (4, l), 0, r)
    store, scale = quantize_counts(sketch, quant)
    ref = sketch_head_logits(store, idx, scale=scale, quant=quant,
                             backend="ref")
    pal = sketch_head_logits(store, idx, scale=scale, quant=quant,
                             backend="pallas", block_v=64)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("quant", ["int8", "int4"])
@pytest.mark.parametrize("l,r,v", [(5, 5, 130), (6, 12, 100)])
def test_fused_decode_quant_pallas_vs_ref(dtype, quant, l, r, v):
    key = jax.random.PRNGKey(l + r + v)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, dp, kk = 16, 8, 2
    hidden = jax.random.normal(k1, (3, d)).astype(dtype)
    proj = jax.random.normal(k2, (d, dp))
    w = jax.random.normal(k3, (l, kk, dp))
    b = jax.random.uniform(k4, (l, kk)) * 2.0
    sketch = jax.random.normal(k5, (l, r, v)) * 3
    store, scale = quantize_counts(sketch, quant)
    kw = dict(bandwidth=2.0, n_buckets=r, scale=scale, quant=quant)
    ref = fused_decode_logits(hidden, proj, w, b, store, backend="ref", **kw)
    pal = fused_decode_logits(hidden, proj, w, b, store, backend="pallas",
                              block_v=64, **kw)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_quant_close_to_f32_head():
    # int8 per-row quantization error on the logits is tiny next to the
    # counts' own magnitude; int4 is coarser but still bounded.
    l, r, v = 8, 6, 200
    key = jax.random.PRNGKey(3)
    sketch = jax.random.normal(key, (l, r, v)) * 3
    idx = jax.random.randint(key, (16, l), 0, r)
    f32 = sketch_head_logits(sketch, idx, backend="ref")
    scale_mag = float(jnp.abs(sketch).max())
    for quant, qmax in (("int8", 127.0), ("int4", 7.0)):
        store, scale = quantize_counts(sketch, quant)
        out = sketch_head_logits(store, idx, scale=scale, quant=quant,
                                 backend="ref")
        # Mean of L independent roundings, each |err| <= scale/2.
        assert float(jnp.abs(out - f32).max()) <= scale_mag / qmax


# ------------------------------------------------- apply_head plumbing

@pytest.fixture(scope="module")
def small_head():
    cfg = SketchHeadConfig(n_rows=6, n_buckets=5, k=2, proj_dim=8,
                           bandwidth=2.0)
    head = _head(jax.random.PRNGKey(5), 16, 130, cfg)
    hidden = jax.random.normal(jax.random.PRNGKey(6), (4, 16))
    return head, cfg, hidden


def test_apply_head_ref_pallas_conflict_raises(small_head):
    head, cfg, hidden = small_head
    # Regression: backend="ref" used to silently overwrite the caller's
    # kernel_backend="pallas" with "ref".
    with pytest.raises(ValueError, match="kernel_backend"):
        apply_head(head, hidden, cfg, backend="ref",
                   kernel_backend="pallas")
    # The non-conflicting spellings still work.
    a = apply_head(head, hidden, cfg, backend="ref")
    b = apply_head(head, hidden, cfg, backend="ref", kernel_backend="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_head_quant_scale_consistency(small_head):
    head, cfg, hidden = small_head
    with pytest.raises(ValueError, match="scale"):
        apply_head(head, hidden, cfg, quant="int8")     # no scale leaf
    qhead = quantize_head(head, "int8")
    with pytest.raises(ValueError, match="scale"):
        apply_head(qhead, hidden, cfg)                  # scale but no quant
    with pytest.raises(ValueError, match="quant"):
        apply_head(head, hidden, cfg, quant="int16")


@pytest.mark.parametrize("quant", ["int8", "int4"])
@pytest.mark.parametrize("backend", ["fused", "two_kernel", "ref"])
def test_apply_head_quant_backends_agree(small_head, quant, backend):
    head, cfg, hidden = small_head
    qhead = quantize_head(head, quant)
    ref = apply_head(qhead, hidden, cfg, backend="ref", quant=quant)
    out = apply_head(qhead, hidden, cfg, backend=backend, quant=quant)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_quantize_dequantize_head_round_trip(small_head):
    head, cfg, _ = small_head
    for quant in ("int8", "int4"):
        qhead = quantize_head(head, quant)
        assert qhead["scale"].shape == (cfg.n_rows, cfg.n_buckets)
        back = dequantize_head(qhead, quant)
        assert back["array"].shape == head["array"].shape
        # Dequant is within one rounding step per count.
        err = jnp.abs(back["array"] - head["array"])
        assert bool(jnp.all(err <= qhead["scale"][:, :, None] * 0.5 + 1e-6))
    with pytest.raises(ValueError, match="already quantized"):
        quantize_head(quantize_head(head, "int8"), "int8")


# ------------------------------------------------- save/load format

def test_save_load_v2_round_trip(tmp_path, small_head):
    head, cfg, _ = small_head
    for quant in (None, "int8", "int4"):
        qhead = quantize_head(head, quant)
        p = tmp_path / f"h_{quant}.npz"
        save_head(p, qhead, cfg, backend="two_kernel", quant=quant)
        h2, cfg2, meta = load_head_full(p)
        assert cfg2 == cfg
        assert meta["format_version"] == HEAD_FORMAT_VERSION
        assert meta["backend"] == "two_kernel"
        assert meta["quant"] == quant
        assert load_head_meta(p) == meta
        for k in qhead:
            np.testing.assert_array_equal(np.asarray(h2[k]),
                                          np.asarray(qhead[k]))
        assert h2["array"].dtype == qhead["array"].dtype


def test_save_head_writes_compressed(tmp_path, small_head):
    head, cfg, _ = small_head
    p = tmp_path / "h.npz"
    save_head(p, head, cfg)
    with zipfile.ZipFile(p) as zf:
        assert all(i.compress_type == zipfile.ZIP_DEFLATED
                   for i in zf.infolist())
        assert "meta_format_version.npy" in zf.namelist()


def test_save_head_quant_mismatch_raises(tmp_path, small_head):
    head, cfg, _ = small_head
    with pytest.raises(ValueError, match="quant"):
        save_head(tmp_path / "bad.npz", head, cfg, quant="int8")


def test_legacy_v1_archive_loads_unchanged():
    # Checked-in archive written by the pre-version save_head (plain
    # np.savez, no meta_format_version / meta_quant / scale).
    p = DATA / "legacy_head_v1.npz"
    head, cfg, meta = load_head_full(p)
    assert meta == {"format_version": 1, "kind": "sketch",
                    "backend": "two_kernel", "quant": None}
    assert cfg == SketchHeadConfig(n_rows=4, n_buckets=3, k=2, proj_dim=6,
                                   bandwidth=2.5)
    assert set(head) == {"proj", "w", "b", "array"}
    assert head["array"].shape == (4, 3, 11)
    # A v1 head must still serve.
    hidden = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    out = apply_head(head, hidden, cfg, backend="ref")
    assert out.shape == (2, 11)
    assert bool(jnp.all(jnp.isfinite(out)))


# ------------------------------------------------- config coercion

def test_sketch_config_coercion_all_fields(tmp_path, small_head):
    head, _, _ = small_head
    # Exercise every SketchHeadConfig field with non-default values.
    cfg = SketchHeadConfig(n_rows=6, n_buckets=5, k=3, proj_dim=9,
                           bandwidth=1.25)
    p = tmp_path / "h.npz"
    save_head(p, head, cfg)
    _, cfg2, _ = load_head_full(p)
    assert cfg2 == cfg
    for f in dataclasses.fields(SketchHeadConfig):
        got, want = getattr(cfg2, f.name), getattr(cfg, f.name)
        assert type(got) is type(want), f.name


def test_coerce_config_mixed_types():
    # The old coercion — (float if "float" in str(typ) else int) — broke on
    # any non-numeric field; the per-field version must handle str, bool,
    # and Optional, from the 0-d arrays an .npz round trip produces.
    @dataclasses.dataclass(frozen=True)
    class Syn:
        count: int = 1
        rate: float = 2.0
        label: str = "x"
        flag: bool = False
        maybe: Optional[int] = None
        maybe_s: Optional[str] = None

    raw = {"count": np.asarray(7), "rate": np.asarray(1.5),
           "label": np.asarray("hey"), "flag": np.asarray(True),
           "maybe": np.asarray(3)}
    got = coerce_config(Syn, raw)
    assert got == Syn(7, 1.5, "hey", True, 3, None)
    assert type(got.count) is int and type(got.flag) is bool
    assert type(got.label) is str and type(got.maybe) is int
    # Missing fields (maybe_s) fall back to defaults — forward compat.
    assert got.maybe_s is None


# ------------------------------------------------- head_costs bytes

def test_head_costs_bytes_ratio():
    cfg = SketchHeadConfig()  # L=64, R=16, k=2, d'=64
    f32 = head_costs(cfg, 1024, 32768)
    i8 = head_costs(cfg, 1024, 32768, quant="int8")
    i4 = head_costs(cfg, 1024, 32768, quant="int4")
    # Count-based fields are quant-invariant (the bug the bytes fields fix).
    assert f32["sketch_params"] == i8["sketch_params"] == i4["sketch_params"]
    assert f32["dense_bytes"] == 4 * f32["dense_params"]
    # The acceptance floors of the paper's storage claim at bench scale.
    assert f32["bytes_ratio"] < 1.1
    assert i8["bytes_ratio"] >= 3.9
    assert i4["bytes_ratio"] >= 7.8
    # int4 halves the count bytes vs int8 (same scales/aux).
    assert i4["sketch_bytes"] < i8["sketch_bytes"]


def test_head_costs_odd_rows_int4():
    cfg = SketchHeadConfig(n_rows=5, n_buckets=4, k=1, proj_dim=8)
    c = head_costs(cfg, 64, 128, quant="int4")
    # ⌈5/2⌉ = 3 packed byte-rows.
    assert c["sketch_bytes"] >= 3 * 4 * 128
