"""Fault-tolerance: supervisor restart/shrink behavior under scripted faults."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import initial_plan, reassign_shards, shrink_plan
from repro.runtime.failure import (Action, HeartbeatRegistry, StragglerTracker,
                                   decide_recovery)
from repro.runtime.supervisor import Supervisor, SupervisorConfig


def test_heartbeat_detects_missing():
    reg = HeartbeatRegistry([0, 1, 2], timeout_s=10)
    reg.beat(0, now=100.0)
    reg.beat(1, now=100.0)
    reg.beat(2, now=85.0)          # stale
    assert reg.missing(now=100.0) == [2]
    assert reg.healthy(now=100.0) == [0, 1]


def test_decide_recovery_modes():
    # no failures
    assert decide_recovery(8, [], hosts_per_replica=2,
                           n_replicas=4).action is Action.CONTINUE
    # one replica lost of 8 → shrink
    p = decide_recovery(16, [3], hosts_per_replica=2, n_replicas=8)
    assert p.action is Action.SHRINK and p.new_data_parallel == 7
    # half the fleet → restart
    p = decide_recovery(8, [0, 2, 4, 6], hosts_per_replica=2, n_replicas=4)
    assert p.action is Action.RESTART


def test_straggler_flag_and_evict():
    t = StragglerTracker(threshold=1.5, evict_after=2)
    for step in range(4):
        t.record(0, 1.0)
        t.record(1, 1.0)
        t.record(2, 3.0)           # persistent straggler
        t.stragglers()
    assert t.to_evict() == [2]


def test_reassign_shards_covers_all():
    plan = initial_plan(8, 2, 16)
    owners = reassign_shards(plan, 16)
    got = sorted(s for shards in owners.values() for s in shards)
    assert got == list(range(16))
    plan2 = shrink_plan(plan, [0], 16)
    owners2 = reassign_shards(plan2, 16)
    assert 0 not in owners2           # dead replica owns nothing
    assert sorted(s for v in owners2.values() for s in v) == list(range(16))


def _make_supervisor(tmp_path, total_steps, fault_hook=None):
    def init_state():
        return {"w": jnp.zeros((4,)), "step_count": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        return {"w": state["w"] + batch["g"],
                "step_count": state["step_count"] + 1}

    def batch_fn(step):
        return {"g": jnp.ones((4,)) * 0.1}

    return Supervisor(
        SupervisorConfig(total_steps=total_steps, ckpt_every=5,
                         ckpt_dir=str(tmp_path), n_hosts=4,
                         hosts_per_replica=1),
        init_state=init_state, step_fn=step_fn, batch_fn=batch_fn,
        fault_hook=fault_hook)


def test_supervisor_clean_run(tmp_path):
    sup = _make_supervisor(tmp_path, 12)
    state = sup.run()
    assert int(state["step_count"]) == 12
    assert ("done", 12, 0) in sup.events


def test_supervisor_restart_from_checkpoint(tmp_path):
    deaths = {8: [0, 1, 2]}   # 3/4 replicas at step 8 → RESTART policy
    sup = _make_supervisor(tmp_path, 12,
                           fault_hook=lambda s: deaths.pop(s, []))
    state = sup.run()
    kinds = [e[0] for e in sup.events]
    assert "restarted" in kinds
    # Restart replayed from the step-5 checkpoint; final count still 12.
    assert int(state["step_count"]) == 12


def test_supervisor_shrinks_on_small_failure(tmp_path):
    deaths = {7: [3]}
    sup = _make_supervisor(tmp_path, 12,
                           fault_hook=lambda s: deaths.pop(s, []))
    sup.run()
    shrunk = [e for e in sup.events if e[0] == "shrunk"]
    assert shrunk and shrunk[0][2] == 3


def test_supervisor_resumes_across_runs(tmp_path):
    sup1 = _make_supervisor(tmp_path, 11)
    sup1.run()
    # New process, same ckpt dir: resumes past the last saved step (10).
    sup2 = _make_supervisor(tmp_path, 20)
    state = sup2.run()
    assert ("restored", 10) in sup2.events
    assert int(state["step_count"]) <= 20 - 10 + 1 + 10  # sanity
