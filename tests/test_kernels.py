"""Per-kernel Pallas (interpret mode) vs pure-jnp oracle, shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_decode.ops import fused_decode_logits
from repro.kernels.fused_decode.ref import fused_decode_ref
from repro.kernels.lsh_hash.ops import lsh_hash
from repro.kernels.lsh_hash.ref import lsh_hash_ref
from repro.kernels.race_query.ops import race_query
from repro.kernels.race_query.ref import race_query_ref
from repro.kernels.race_update.ops import race_update
from repro.kernels.race_update.ref import race_update_ref
from repro.kernels.sketch_head.ops import sketch_head_logits
from repro.kernels.sketch_head.ref import sketch_head_ref


@pytest.mark.parametrize("b", [1, 7, 128, 130])
@pytest.mark.parametrize("d,l,k,r", [(8, 16, 1, 8), (64, 40, 3, 32),
                                     (17, 5, 2, 100)])
def test_lsh_hash_matches_ref(b, d, l, k, r):
    key = jax.random.PRNGKey(b * 1000 + d)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, d))
    w = jax.random.normal(kw, (l, k, d))
    bias = jax.random.uniform(kb, (l, k))
    got = lsh_hash(x, w, bias, bandwidth=1.5, n_buckets=r, block_b=32)
    want = lsh_hash_ref(x, w, bias, 1.5, r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32
    assert bool(jnp.all((got >= 0) & (got < r)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lsh_hash_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 8)).astype(dtype)
    w = jax.random.normal(key, (4, 2, 8))
    b = jax.random.uniform(key, (4, 2))
    got = lsh_hash(x.astype(jnp.float32), w, b, bandwidth=1.0, n_buckets=8)
    assert got.shape == (16, 4)


@pytest.mark.parametrize("b,c,l,r,g", [(4, 1, 8, 4, 2), (33, 5, 40, 16, 8),
                                       (128, 2, 100, 20, 10)])
def test_race_query_matches_ref(b, c, l, r, g):
    key = jax.random.PRNGKey(b + c)
    sketch = jax.random.normal(key, (c, l, r))
    idx = jax.random.randint(key, (b, l), 0, r)
    got = race_query(sketch, idx, n_groups=g, block_b=16)
    want = race_query_ref(sketch, idx, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,c,l,r", [(10, 1, 8, 4), (300, 5, 40, 16),
                                     (257, 3, 20, 32)])
def test_race_update_matches_ref(m, c, l, r):
    key = jax.random.PRNGKey(m)
    sketch = jax.random.normal(key, (c, l, r))
    idx = jax.random.randint(key, (m, l), 0, r)
    alphas = jax.random.normal(key, (m, c))
    got = race_update(sketch, idx, alphas, block_m=64)
    want = race_update_ref(sketch, idx, alphas)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,c,l,r,g", [(5, 3, 24, 12, 6),    # all non-pow2
                                       (33, 2, 18, 10, 1),   # g=1: plain mean
                                       (130, 4, 50, 6, 5)])  # b > block_b
def test_race_query_pallas_vs_ref_explicit(b, c, l, r, g, dtype):
    """Explicit backend pin: the pallas kernel against the jnp oracle, both
    resolved by name — immune to REPRO_KERNEL_BACKEND / default-backend
    flips — over non-power-of-two shapes and reduced-precision sketches."""
    key = jax.random.PRNGKey(b * 7 + c)
    sketch = jax.random.normal(key, (c, l, r)).astype(dtype)
    idx = jax.random.randint(key, (b, l), 0, r)
    got = race_query(sketch, idx, n_groups=g, block_b=16, backend="pallas")
    want = race_query(sketch, idx, n_groups=g, backend="ref")
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,c,l,r", [(37, 3, 12, 6),     # all non-pow2
                                     (129, 2, 25, 10),   # m % block_m != 0
                                     (64, 5, 18, 12)])
def test_race_update_pallas_vs_ref_explicit(m, c, l, r, dtype):
    """Explicit backend pin for the construction kernel: pallas scatter-add
    vs the jnp oracle over ragged point counts and reduced precision (the
    accumulate path the distillation freeze runs)."""
    key = jax.random.PRNGKey(m * 3 + c)
    sketch = jax.random.normal(key, (c, l, r)).astype(dtype)
    idx = jax.random.randint(key, (m, l), 0, r)
    alphas = jax.random.normal(key, (m, c)).astype(dtype)
    got = race_update(sketch, idx, alphas, block_m=32, backend="pallas")
    want = race_update(sketch, idx, alphas, backend="ref")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,l,r,v", [(2, 8, 4, 16), (9, 64, 16, 100),
                                     (16, 32, 8, 2048)])
def test_sketch_head_matches_ref(b, l, r, v):
    key = jax.random.PRNGKey(v)
    sketch = jax.random.normal(key, (l, r, v))
    idx = jax.random.randint(key, (b, l), 0, r)
    got = sketch_head_logits(sketch, idx, block_b=4, block_v=64)
    want = sketch_head_ref(sketch, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 7, 16])
@pytest.mark.parametrize("d,dp,l,k,r,v", [(16, 8, 8, 1, 4, 32),
                                          (64, 32, 40, 3, 16, 100),
                                          (24, 16, 5, 2, 100, 2048)])
def test_fused_decode_matches_ref(b, d, dp, l, k, r, v):
    key = jax.random.PRNGKey(b * 1000 + v)
    kh, kp, kw, kb, ks = jax.random.split(key, 5)
    hidden = jax.random.normal(kh, (b, d))
    proj = jax.random.normal(kp, (d, dp)) / np.sqrt(d)
    w = jax.random.normal(kw, (l, k, dp))
    bias = jax.random.uniform(kb, (l, k))
    sketch = jax.random.normal(ks, (l, r, v))
    got = fused_decode_logits(hidden, proj, w, bias, sketch, bandwidth=1.5,
                              n_buckets=r, block_b=4, block_v=64)
    want = fused_decode_ref(hidden, proj, w, bias, sketch, 1.5, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_decode_matches_two_kernel_composition():
    """The fused kernel must agree with lsh_hash → sketch_head exactly on
    indices (same integer mix), hence near-exactly on logits."""
    key = jax.random.PRNGKey(42)
    kh, kp, kw, kb, ks = jax.random.split(key, 5)
    b, d, dp, l, k, r, v = 9, 32, 16, 24, 2, 8, 128
    hidden = jax.random.normal(kh, (b, d))
    proj = jax.random.normal(kp, (d, dp)) / np.sqrt(d)
    w = jax.random.normal(kw, (l, k, dp))
    bias = jax.random.uniform(kb, (l, k))
    sketch = jax.random.normal(ks, (l, r, v))
    fused = fused_decode_logits(hidden, proj, w, bias, sketch, bandwidth=2.0,
                                n_buckets=r, block_b=4, block_v=64)
    idx = lsh_hash(hidden @ proj, w, bias, bandwidth=2.0, n_buckets=r)
    two = sketch_head_logits(sketch, idx, block_b=4, block_v=64)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                               rtol=1e-5, atol=1e-5)


def test_kernels_jit_and_grad_free():
    """Kernels are inference-path ops; they must compose under jit."""
    key = jax.random.PRNGKey(0)
    sketch = jax.random.normal(key, (3, 16, 8))
    idx = jax.random.randint(key, (5, 16), 0, 8)

    @jax.jit
    def f(s, i):
        return race_query(s, i, n_groups=4)

    out = f(sketch, idx)
    assert out.shape == (5, 3)


@pytest.mark.parametrize("s,win,cap,bq,bk", [
    (96, None, None, 32, 32),
    (200, 64, None, 64, 64),     # non-divisible seq + sliding window
    (128, None, 50.0, 32, 64),   # gemma2-style softcap, rectangular tiles
    (256, 32, 30.0, 128, 128),   # window + softcap combined
])
def test_flash_attention_matches_ref(s, win, cap, bq, bk):
    from repro.kernels.flash_attn.ops import flash_attention
    from repro.kernels.flash_attn.ref import flash_attention_ref

    b, h, dh = 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, dh))
    got = flash_attention(q, k, v, window=win, softcap=cap,
                          block_q=bq, block_k=bk)
    want = flash_attention_ref(q, k, v, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attn.ops import flash_attention
    from repro.kernels.flash_attn.ref import flash_attention_ref

    q = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 2, 32)).astype(dtype)
    got = flash_attention(q, k, v, block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,d,l,k,r", [(37, 10, 12, 1, 6),    # all non-pow2
                                       (129, 18, 25, 2, 10),  # b % block != 0
                                       (64, 24, 18, 3, 12)])  # K-fold rehash
def test_lsh_hash_pallas_vs_ref_explicit(b, d, l, k, r, dtype):
    """Explicit backend pin for the hash kernel: the pallas projection +
    floor + K-fold integer mix against the jnp oracle, both resolved by
    name — immune to REPRO_KERNEL_BACKEND / default-backend flips.  Bucket
    indices are discrete, so parity is *exact*: both paths accumulate the
    projection in f32 (``preferred_element_type``), and the mix is integer
    arithmetic with one bit-for-bit convention (kernel docstring)."""
    key = jax.random.PRNGKey(b * 11 + d)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, d)).astype(dtype)
    w = jax.random.normal(kw, (l, k, d))
    bias = jax.random.uniform(kb, (l, k)) * 1.5
    got = lsh_hash(x, w, bias, bandwidth=1.5, n_buckets=r, block_b=16,
                   backend="pallas")
    want = lsh_hash(x, w, bias, bandwidth=1.5, n_buckets=r, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32
    assert bool(jnp.all((got >= 0) & (got < r)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,win,cap,bq,bk", [
    (96, None, None, 32, 32),     # plain causal, seq % block == 0
    (200, 64, None, 64, 64),      # non-divisible seq + sliding window
    (100, None, 50.0, 32, 64),    # softcap + non-pow2 seq, rect tiles
    (144, 32, 30.0, 48, 48),      # window + softcap, non-pow2 blocks
])
def test_flash_attention_pallas_vs_ref_explicit(s, win, cap, bq, bk, dtype):
    """Explicit backend pin for attention: the pallas online-softmax tiles
    against the jnp oracle across the window/softcap feature grid and both
    serving dtypes — f32 at tight tolerance, bf16 at storage precision."""
    from repro.kernels.flash_attn.ops import flash_attention

    b, h, dh = 2, 2, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(s + bq), 3)
    q = jax.random.normal(kq, (b, s, h, dh)).astype(dtype)
    k = jax.random.normal(kk, (b, s, h, dh)).astype(dtype)
    v = jax.random.normal(kv, (b, s, h, dh)).astype(dtype)
    got = flash_attention(q, k, v, window=win, softcap=cap, block_q=bq,
                          block_k=bk, backend="pallas")
    want = flash_attention(q, k, v, window=win, softcap=cap, backend="ref")
    assert got.dtype == want.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
