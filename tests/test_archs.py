"""Per-architecture smoke tests: reduced configs, one forward + one decode
step on CPU, shape + NaN assertions, decode-vs-prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_names, get_config
from repro.models.config import active_param_count, param_count
from repro.models.model import (decode_step, forward, init_decode_cache,
                                init_model, lm_loss)

ARCHS = arch_names()


@pytest.fixture(scope="module")
def setups():
    out = {}
    key = jax.random.PRNGKey(0)
    for name in ARCHS:
        cfg = get_config(name, smoke=True)
        out[name] = (cfg, init_model(key, cfg))
    return out


def _enc(cfg, b, key):
    if not cfg.n_encoder_tokens:
        return None
    return jax.random.normal(key, (b, cfg.n_encoder_tokens, cfg.d_model),
                             dtype=jnp.bfloat16)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name, setups):
    cfg, params = setups[name]
    b, s = 2, 16
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, _, aux = forward(params, toks, cfg, encoder_states=_enc(cfg, b, key))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert float(aux) >= 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_loss_finite(name, setups):
    cfg, params = setups[name]
    b, s = 2, 16
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    loss, parts = lm_loss(params, toks, labels, cfg,
                          encoder_states=_enc(cfg, b, key))
    assert np.isfinite(float(loss))
    # Random labels over V classes: CE should be near log(V).
    assert abs(float(parts["ce"]) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name, setups):
    cfg, params = setups[name]
    if cfg.moe is not None:
        # Capacity dropping differs between prefill and decode by design;
        # disable dropping for the equivalence check.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    b, s = 2, 10
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc = _enc(cfg, b, key)
    full, _, _ = forward(params, toks, cfg, encoder_states=enc, remat=False)
    cache = init_decode_cache(cfg, b, 16)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.asarray(t, jnp.int32), cfg,
                                encoder_states=enc)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    err = float(jnp.max(jnp.abs(dec - full))) / scale
    # Recurrent blocks accumulate in a different order between the chunked
    # train scan and the single-step decode recurrence; in bf16 that costs
    # ~1e-1 relative at random-init logit scale (verified 1.8e-3 in f32).
    tol = 0.2 if any(k in cfg.pattern for k in ("mamba", "rwkv")) else 0.08
    assert err < tol, f"{name}: rel decode mismatch {err}"


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_matches_init(name, setups):
    cfg, params = setups[name]
    analytic = param_count(cfg)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    # Analytic model omits tiny per-block extras (biases, mixing vectors);
    # require agreement within 5%.
    assert abs(actual - analytic) / actual < 0.05, (name, actual, analytic)
    assert active_param_count(cfg) <= analytic


def test_full_config_param_counts():
    """Full (non-smoke) configs match their published parameter scales."""
    expected_b = {   # billions, generous bands (vocab/head variants differ)
        "stablelm-12b": (10, 14),
        "gemma2-27b": (24, 30),
        "granite-8b": (7, 9.5),
        "command-r-35b": (28, 40),   # 30.3B with the assigned dims (SwiGLU)
        "mixtral-8x7b": (42, 50),       # total (not active) params
        "deepseek-v3-671b": (600, 720),
        "llama-3.2-vision-11b": (8, 12),  # backbone only (frontend stubbed)
        "rwkv6-1.6b": (1.2, 2.2),
        "jamba-v0.1-52b": (48, 58),
        "musicgen-large": (2.6, 3.8),  # 3.3B per hf (decoder incl. head)
    }
    for name, (lo, hi) in expected_b.items():
        n = param_count(get_config(name)) / 1e9
        assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"
