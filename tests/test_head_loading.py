"""Head-archive loading: the single-open contract and identity round-trip.

Regression for the ``load_head`` double-open bug: the registry loader used
to read the archive once for the metadata (to pick the class) and then a
second time inside the class's ``load`` — two full decompressions of a
count array that dominates the artifact.  The fix threads one
``load_head_full`` read through ``from_archive``, so loading a head opens
the archive exactly once.
"""

import jax
import numpy as np
import pytest

import repro.core.sketch_lm_head as head_mod
from repro.api.heads import SketchHead, load_head
from repro.core.sketch_lm_head import freeze_head, save_head
from repro.models.config import SketchHeadConfig

_HEAD_CFG = SketchHeadConfig(n_rows=16, n_buckets=8, k=1, proj_dim=8,
                             bandwidth=2.0)


def _saved_head(tmp_path, quant=None, backend="fused"):
    d_model, vocab = 12, 32
    kp, ka, kj, kf = jax.random.split(jax.random.PRNGKey(5), 4)
    kparams = {
        "points": jax.random.normal(kp, (32, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (32, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, _HEAD_CFG.proj_dim)),
    }
    params = freeze_head(kf, kparams, _HEAD_CFG, quant=quant)
    path = tmp_path / "head.npz"
    save_head(path, params, _HEAD_CFG, kind="sketch", backend=backend,
              quant=quant)
    return path, params


@pytest.mark.parametrize("quant", [None, "int8"])
def test_load_head_opens_archive_exactly_once(tmp_path, monkeypatch, quant):
    path, _ = _saved_head(tmp_path, quant=quant)
    opens = []
    real_load = np.load

    def counting_load(file, *args, **kwargs):
        opens.append(file)
        return real_load(file, *args, **kwargs)

    # Every archive read in the loading stack goes through the
    # sketch_lm_head module's np binding (load_head_full/load_head_meta).
    monkeypatch.setattr(head_mod.np, "load", counting_load)
    head = load_head(path)
    assert len(opens) == 1, (
        f"load_head opened the archive {len(opens)} times: {opens}")
    assert isinstance(head, SketchHead)


@pytest.mark.parametrize("quant,backend", [(None, "fused"),
                                           (None, "two_kernel"),
                                           ("int8", "ref")])
def test_load_head_round_trips_identity_and_params(tmp_path, quant, backend):
    """The loaded head serves on the path it was saved with: kind, backend,
    quant, config, and every param leaf survive the round trip."""
    path, params = _saved_head(tmp_path, quant=quant, backend=backend)
    head = load_head(path)
    assert isinstance(head, SketchHead)
    assert head.backend == backend
    assert head.quant == quant
    assert head.cfg == _HEAD_CFG
    assert set(head.params) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(head.params[k]),
                                      np.asarray(params[k]))


def test_sketch_head_class_load_matches_registry_load(tmp_path):
    """SketchHead.load (the class entry point) and load_head (the registry
    entry point) produce identical heads."""
    path, _ = _saved_head(tmp_path)
    a, b = SketchHead.load(path), load_head(path)
    assert a.backend == b.backend and a.quant == b.quant and a.cfg == b.cfg
    for k in a.params:
        np.testing.assert_array_equal(np.asarray(a.params[k]),
                                      np.asarray(b.params[k]))
