"""Statistical properties of the weighted RACE sketch (Theorems 1–2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RepresenterSketch, SketchConfig, theory


def _setup(l=600, r=16, k=1, dim=6, c=1, bw=2.0, m=300, seed=0):
    cfg = SketchConfig(n_rows=l, n_buckets=r, k=k, dim=dim, n_outputs=c,
                       bandwidth=bw, n_groups=8)
    sk = RepresenterSketch(cfg)
    kp, kd, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    pts = jax.random.normal(kd, (m, dim))
    alphas = jax.random.normal(kp, (m, c))
    queries = jax.random.normal(kq, (8, dim))
    return sk, pts, alphas, queries


def test_unbiasedness_row_estimator():
    """E[S[h(q)]] == weighted KDE: average row reads over many rows."""
    sk, pts, alphas, queries = _setup(l=4000)
    state = sk.init(jax.random.PRNGKey(1))
    state = sk.build(state, pts, alphas)
    mean_est = sk.query(state, queries, mom=False)  # debiased plain mean
    exact = sk.exact_weighted_kde(pts, alphas, queries)
    # With L=4000 i.i.d. unbiased rows, the mean is within a few σ/√L.
    err = np.abs(np.asarray(mean_est - exact))
    scale = np.abs(np.asarray(exact)).mean() + 1.0
    assert err.mean() / scale < 0.15, (err.mean(), scale)


def test_theorem2_error_bound_holds():
    """MoM error ≤ 6·σ̃/√L·√log(1/δ) for ≥ (1−δ) of queries."""
    delta = 0.05
    sk, pts, alphas, _ = _setup(l=800)
    queries = jax.random.normal(jax.random.PRNGKey(7), (100, 6))
    state = sk.init(jax.random.PRNGKey(2))
    state = sk.build(state, pts, alphas)
    est = sk.query(state, queries)                  # MoM
    exact = sk.exact_weighted_kde(pts, alphas, queries)
    # σ bound from Theorem 1: Σ|α|·√K  (use |α| for a valid bound with
    # signed weights — Cauchy–Schwarz is agnostic to sign).
    dist = jnp.linalg.norm(queries[:, None] - pts[None], axis=-1)
    sqrt_k = jnp.sqrt(sk.lsh.collision_probability(dist))
    sigma = sqrt_k @ jnp.abs(alphas)
    bound = 6.0 * sigma / np.sqrt(sk.config.n_rows) * np.sqrt(np.log(1 / delta))
    violations = np.mean(np.abs(np.asarray(est - exact)) > np.asarray(bound))
    assert violations <= delta + 0.02, violations


def test_build_streaming_equals_build():
    sk, pts, alphas, queries = _setup()
    s1 = sk.build(sk.init(jax.random.PRNGKey(3)), pts, alphas)
    s2 = sk.build_streaming(sk.init(jax.random.PRNGKey(3)), pts, alphas,
                            chunk=37)
    np.testing.assert_allclose(np.asarray(s1["array"]),
                               np.asarray(s2["array"]), rtol=1e-5, atol=1e-5)


def test_sketch_linearity():
    """Sketching is linear in the weights (it is a sum of increments)."""
    sk, pts, alphas, _ = _setup(c=2)
    a1 = alphas
    a2 = jnp.flip(alphas, axis=0)
    init = sk.init(jax.random.PRNGKey(4))
    s12 = sk.build(init, pts, a1 + a2)
    s1 = sk.build(init, pts, a1)
    s2 = sk.build(init, pts, a2)
    np.testing.assert_allclose(
        np.asarray(s12["array"]),
        np.asarray(s1["array"] + s2["array"] - init["array"]),
        rtol=1e-4, atol=1e-4)


def test_mom_equals_mean_for_uniform_rows():
    """If all rows agree, MoM == mean == debiased row value."""
    cfg = SketchConfig(n_rows=16, n_buckets=4, k=1, dim=3, n_outputs=1)
    sk = RepresenterSketch(cfg)
    state = sk.init(jax.random.PRNGKey(0))
    state["array"] = jnp.ones_like(state["array"]) * 2.5
    q = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
    out = sk.query(state, q)
    # zero inserted mass → debias = x / (1 − 1/R)
    np.testing.assert_allclose(np.asarray(out), 2.5 / (1 - 0.25), rtol=1e-6)


def test_rehash_floor_debiasing():
    """Signed-weight sketches: the Σα/R floor is removed by the query."""
    sk, pts, alphas, queries = _setup(l=1500, r=8, seed=3)
    alphas = alphas + 0.5   # nonzero total mass → visible floor if unbiased
    state = sk.build(sk.init(jax.random.PRNGKey(9)), pts, alphas)
    # Plain-mean query: exactly unbiased after the floor correction (MoM's
    # median has its own small skew bias, irrelevant here).
    est = sk.query(state, queries, mom=False)
    exact = sk.exact_weighted_kde(pts, alphas, queries)
    bias = float(jnp.mean(est - exact))
    floor = float(jnp.sum(alphas)) / sk.config.n_buckets
    # Without debiasing the mean offset would be ≈ floor·(1−p̄) ≫ tolerance.
    assert abs(bias) < 0.15 * abs(floor), (bias, floor)


def test_theory_helpers_roundtrip():
    l = theory.rows_for_error(sigma=2.0, eps=0.5, delta=0.05)
    assert theory.mom_error_bound(2.0, l, 0.05) <= 0.5 + 1e-9
    assert theory.mom_groups(0.05) == int(np.ceil(8 * np.log(20)))


def test_memory_accounting():
    cfg = SketchConfig(n_rows=100, n_buckets=10, k=2, dim=5, n_outputs=3)
    assert cfg.memory_floats == 3 * 100 * 10
