"""Per-tenant hot-swappable sketch heads (DESIGN.md §14).

The acceptance bar of the per-tenant redesign:

* **Multi-vs-single-tenant bitwise parity** — a per-tenant engine whose
  slots are bound to different tenants emits, for every request, exactly
  the token stream a plain single-tenant engine bound to that request's
  head emits on the identical workload (same requests, same slots, same
  sampler PRNG stream) — greedy and seeded, across all three decode
  backends, and on the forced-CPU 4×2 mesh.
* **Eviction transparency** — with HeadCache capacity 1 and three tenants
  interleaved, every bank row is evicted and reloaded mid-stream; the
  streams still match each tenant's solo run bitwise.
* **Online refresh** — ``refresh_head`` with ``alphas=`` is the streaming
  equivalent of ``freeze_head`` over the augmented anchor set (same
  einsum, so equal up to f32 summation order); ``targets=`` is the
  residual fold.  The engine's double-buffered ``refresh``/``publish``
  keeps in-flight decodes bitwise untouched until publish, and a
  refresh-then-publish on a quantized head matches offline re-freezing
  the augmented set within quantization tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import HeadCache, Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import (apply_head, dequantize_head,
                                       freeze_head, refresh_head)
from repro.launch.engine import make_engine
from repro.models.model import init_model

_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)


def _kernel_params(key, d_model, vocab, cfg=_HEAD_CFG, n_points=128):
    kp, ka, kj = jax.random.split(key, 3)
    return {
        "points": jax.random.normal(kp, (n_points, cfg.proj_dim)),
        "alphas": jax.random.normal(ka, (n_points, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }


def _tenant_archive(cfg, n_tenants, quant=None):
    """Per-tenant frozen banks sharing one spec (the HeadCache loader's
    backing store): same kernel params, per-tenant freeze keys — distinct
    count arrays and hash banks, identical shapes/dtypes."""
    kparams = _kernel_params(jax.random.PRNGKey(3), cfg.d_model,
                             cfg.vocab_size)
    return {f"tenant-{t}": freeze_head(jax.random.PRNGKey(100 + t),
                                       kparams, _HEAD_CFG, quant=quant)
            for t in range(n_tenants)}


@pytest.fixture(scope="module")
def served():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params, _tenant_archive(cfg, 3)


def _requests(cfg, n, plen=5):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(20 + i),
                                          (plen,), 0, cfg.vocab_size))
            for i in range(n)]


def _run_multi(params, cfg, archive, reqs, tenants, *, backend,
               sampler=None, capacity=None, n_slots=None, gen=4, mesh=None):
    spec = SketchHead(cfg=_HEAD_CFG, backend=backend)
    cache = HeadCache(archive.__getitem__,
                      capacity=capacity or len(archive))
    engine = make_engine(params, cfg, n_slots=n_slots or len(reqs),
                         max_seq=len(reqs[0]) + gen, head=spec,
                         sampler=sampler, head_cache=cache, mesh=mesh)
    rids = [engine.submit(p, gen, tenant=t) for p, t in zip(reqs, tenants)]
    return engine.run(), rids, cache


def _run_single(params, cfg, head_params, reqs, *, backend, sampler=None,
                n_slots=None, gen=4, mesh=None):
    """The identical workload through a plain engine bound to one head —
    same requests in the same slots, so the sampler PRNG stream and batch
    composition match the multi-tenant run exactly."""
    head = SketchHead(cfg=_HEAD_CFG, backend=backend, params=head_params)
    engine = make_engine(params, cfg, n_slots=n_slots or len(reqs),
                         max_seq=len(reqs[0]) + gen, head=head,
                         sampler=sampler, mesh=mesh)
    rids = [engine.submit(p, gen) for p in reqs]
    return engine.run(), rids


# ------------------------------------------------- multi-vs-single parity

@pytest.mark.parametrize("backend,sampler_kind", [
    ("fused", "greedy"), ("two_kernel", "greedy"), ("ref", "greedy"),
    ("fused", "seeded"),
])
def test_multi_tenant_matches_single_tenant(served, backend, sampler_kind):
    """Each slot decodes through its own tenant's bank: row b of the
    per-tenant megastep must be bitwise row b of the single-tenant path
    bound to that tenant — greedy and seeded (the seeded run pins the
    whole PRNG-threading path: same key splits, same tick count)."""
    cfg, params, archive = served
    sampler = (Sampler(temperature=0.8, top_p=0.9, seed=5)
               if sampler_kind == "seeded" else None)
    reqs = _requests(cfg, 3)
    tenants = [f"tenant-{t}" for t in range(3)]
    multi, rids, cache = _run_multi(params, cfg, archive, reqs, tenants,
                                    backend=backend, sampler=sampler)
    assert cache.stats["loads"] == 3 and cache.stats["evictions"] == 0
    for t, tenant in enumerate(tenants):
        solo, solo_rids = _run_single(params, cfg, archive[tenant], reqs,
                                      backend=backend, sampler=sampler)
        np.testing.assert_array_equal(
            np.asarray(multi[rids[t]]), np.asarray(solo[solo_rids[t]]),
            err_msg=f"{backend}/{sampler_kind}: row {t} ({tenant}) diverged "
                    f"from the single-tenant engine")


def test_eviction_and_reload_are_bitwise_transparent(served):
    """Capacity 1, three tenants interleaved one slot at a time: every
    request evicts the previous tenant's bank and (re)loads its own, and
    every stream still matches that tenant's solo engine."""
    cfg, params, archive = served
    reqs = _requests(cfg, 6)
    tenants = [f"tenant-{i % 3}" for i in range(6)]
    multi, rids, cache = _run_multi(params, cfg, archive, reqs, tenants,
                                    backend="fused", capacity=1, n_slots=1)
    assert cache.stats["loads"] == 6           # every admit is a cold miss
    assert cache.stats["evictions"] == 5
    for t in range(3):
        mine = [i for i in range(6) if tenants[i] == f"tenant-{t}"]
        solo, solo_rids = _run_single(params, cfg, archive[f"tenant-{t}"],
                                      [reqs[i] for i in mine],
                                      backend="fused", n_slots=1)
        for j, i in enumerate(mine):
            np.testing.assert_array_equal(
                np.asarray(multi[rids[i]]),
                np.asarray(solo[solo_rids[j]]),
                err_msg=f"tenant-{t} request {i} diverged after paging")


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
@pytest.mark.parametrize("backend", ["ref", "fused"])
def test_multi_tenant_parity_on_mesh(served, backend):
    """The per-slot tenant gather composes with the 4×2 shard_map head
    path (count arrays partitioned over ``model``, one psum per step):
    on-mesh multi-tenant rows == on-mesh single-tenant rows, bitwise."""
    from repro.launch.mesh import parse_mesh, place_serving_state

    cfg, params, archive = served
    mesh = parse_mesh("4x2")
    spec = SketchHead(cfg=_HEAD_CFG, backend=backend,
                      params=archive["tenant-0"])
    placed, _ = place_serving_state(params, spec, mesh)
    reqs = _requests(cfg, 3)
    tenants = [f"tenant-{t}" for t in range(3)]
    multi, rids, _ = _run_multi(placed, cfg, archive, reqs, tenants,
                                backend=backend, mesh=mesh)
    for t, tenant in enumerate(tenants):
        _, head_t = place_serving_state(
            placed, SketchHead(cfg=_HEAD_CFG, backend=backend,
                               params=archive[tenant]), mesh)
        solo, solo_rids = _run_single(placed, cfg, head_t.params, reqs,
                                      backend=backend, mesh=mesh)
        np.testing.assert_array_equal(
            np.asarray(multi[rids[t]]), np.asarray(solo[solo_rids[t]]),
            err_msg=f"mesh/{backend}: row {t} ({tenant}) diverged")


# --------------------------------------------------------- online refresh

def test_refresh_alphas_matches_freeze_over_augmented_anchors():
    """The streaming fold is freeze_head over the augmented anchor set:
    same hash bank (same key), counts equal up to f32 summation order."""
    d_model, vocab = 24, 64
    kparams = _kernel_params(jax.random.PRNGKey(1), d_model, vocab,
                             n_points=48)
    head0 = freeze_head(jax.random.PRNGKey(7), kparams, _HEAD_CFG)
    hidden = jax.random.normal(jax.random.PRNGKey(2), (16, d_model))
    new_alphas = jax.random.normal(jax.random.PRNGKey(4), (16, vocab)) * 0.05
    incremental = refresh_head(head0, _HEAD_CFG, hidden, alphas=new_alphas)
    augmented = freeze_head(jax.random.PRNGKey(7), {
        "points": jnp.concatenate(
            [kparams["points"],
             hidden.astype(jnp.float32) @ kparams["proj"]]),
        "alphas": jnp.concatenate([kparams["alphas"], new_alphas]),
        "proj": kparams["proj"],
    }, _HEAD_CFG)
    for k in ("proj", "w", "b"):
        np.testing.assert_array_equal(np.asarray(incremental[k]),
                                      np.asarray(augmented[k]))
    np.testing.assert_allclose(np.asarray(incremental["array"]),
                               np.asarray(augmented["array"]),
                               rtol=1e-5, atol=1e-5)


def test_refresh_targets_is_the_residual_fold():
    """``targets=`` folds ``lr · (targets − f(hidden))`` — bitwise the
    ``alphas=`` path fed the residual computed through the ref head."""
    d_model, vocab = 24, 64
    kparams = _kernel_params(jax.random.PRNGKey(1), d_model, vocab,
                             n_points=48)
    head0 = freeze_head(jax.random.PRNGKey(7), kparams, _HEAD_CFG)
    hidden = jax.random.normal(jax.random.PRNGKey(2), (8, d_model))
    targets = jax.random.normal(jax.random.PRNGKey(5), (8, vocab))
    pred = apply_head(head0, hidden, _HEAD_CFG, backend="ref")
    via_targets = refresh_head(head0, _HEAD_CFG, hidden, targets=targets,
                               lr=0.5)
    via_alphas = refresh_head(head0, _HEAD_CFG, hidden,
                              alphas=0.5 * (targets - pred))
    np.testing.assert_array_equal(np.asarray(via_targets["array"]),
                                  np.asarray(via_alphas["array"]))


def test_refresh_rejects_quantized_working_copy():
    d_model, vocab = 24, 64
    kparams = _kernel_params(jax.random.PRNGKey(1), d_model, vocab,
                             n_points=48)
    head_q = freeze_head(jax.random.PRNGKey(7), kparams, _HEAD_CFG,
                         quant="int8")
    hidden = jax.random.normal(jax.random.PRNGKey(2), (4, d_model))
    alphas = jnp.zeros((4, vocab))
    with pytest.raises(ValueError, match="dequantize the head first"):
        refresh_head(head_q, _HEAD_CFG, hidden, alphas=alphas)
    with pytest.raises(ValueError, match="exactly one of"):
        refresh_head(dequantize_head(head_q, "int8"), _HEAD_CFG, hidden)


def test_inflight_decodes_unchanged_until_publish(served):
    """Double buffering: refreshes accumulate in the shadow copy; the
    published bank row — and therefore every decode — stays bitwise
    unchanged until ``publish`` commits, at which point new decodes serve
    the folded head exactly as a fresh engine loading it would."""
    cfg, params, archive = served
    reqs = _requests(cfg, 1)
    gen = 8
    baseline, rids, _ = _run_multi(params, cfg, archive, reqs, ["tenant-0"],
                                   backend="fused", gen=gen)

    spec = SketchHead(cfg=_HEAD_CFG, backend="fused")
    cache = HeadCache(archive.__getitem__, capacity=1)
    engine = make_engine(params, cfg, n_slots=1, max_seq=len(reqs[0]) + gen,
                         head=spec, head_cache=cache)
    rid = engine.submit(reqs[0], gen, tenant="tenant-0")
    engine.step()
    engine.step()
    hidden = jax.random.normal(jax.random.PRNGKey(9), (32, cfg.d_model))
    alphas = jax.random.normal(jax.random.PRNGKey(11), (32, cfg.vocab_size))
    engine.refresh("tenant-0", hidden, alphas=alphas)
    out = engine.run()
    np.testing.assert_array_equal(np.asarray(out[rid]),
                                  np.asarray(baseline[rids[0]]))
    np.testing.assert_array_equal(                  # bank row untouched too
        np.asarray(cache.tenant_params("tenant-0")["array"]),
        np.asarray(archive["tenant-0"]["array"]))

    engine.publish("tenant-0")
    published = cache.tenant_params("tenant-0")
    assert not np.array_equal(np.asarray(published["array"]),
                              np.asarray(archive["tenant-0"]["array"]))
    rid2 = engine.submit(reqs[0], gen, tenant="tenant-0")
    after = engine.run()
    fresh, fresh_rids, _ = _run_multi(
        params, cfg, {"tenant-0": published}, reqs, ["tenant-0"],
        backend="fused", gen=gen)
    np.testing.assert_array_equal(np.asarray(after[rid2]),
                                  np.asarray(fresh[fresh_rids[0]]))
    assert engine.stats["refreshes"] == 1 and engine.stats["publishes"] == 1


def test_quantized_refresh_publish_matches_offline_refreeze(served):
    """On an int8 archive the engine dequantizes into the f32 shadow,
    folds, and re-quantizes on publish — the published head's logits must
    track offline re-freezing the augmented anchor set with quant="int8"
    within quantization tolerance (the base counts round-trip int8 once,
    so bitwise equality is not available; argmax agreement is)."""
    cfg, params, _ = served
    kparams = _kernel_params(jax.random.PRNGKey(3), cfg.d_model,
                             cfg.vocab_size)
    archive = {"tenant-0": freeze_head(jax.random.PRNGKey(100), kparams,
                                       _HEAD_CFG, quant="int8")}
    spec = SketchHead(cfg=_HEAD_CFG, backend="fused", quant="int8")
    cache = HeadCache(archive.__getitem__, capacity=1)
    engine = make_engine(params, cfg, n_slots=1, max_seq=16, head=spec,
                         head_cache=cache)
    engine.submit(_requests(cfg, 1)[0], 2, tenant="tenant-0")
    engine.run()

    hidden = jax.random.normal(jax.random.PRNGKey(9), (24, cfg.d_model))
    alphas = jax.random.normal(jax.random.PRNGKey(11),
                               (24, cfg.vocab_size)) * 0.01
    engine.refresh("tenant-0", hidden, alphas=alphas)
    engine.publish("tenant-0")
    published = cache.tenant_params("tenant-0")

    offline = freeze_head(jax.random.PRNGKey(100), {
        "points": jnp.concatenate(
            [kparams["points"],
             hidden.astype(jnp.float32) @ kparams["proj"]]),
        "alphas": jnp.concatenate([kparams["alphas"], alphas]),
        "proj": kparams["proj"],
    }, _HEAD_CFG, quant="int8")
    probe = jax.random.normal(jax.random.PRNGKey(13), (32, cfg.d_model))
    got = np.asarray(apply_head(published, probe, _HEAD_CFG, backend="ref",
                                quant="int8"))
    want = np.asarray(apply_head(offline, probe, _HEAD_CFG, backend="ref",
                                 quant="int8"))
    # One extra int8 round-trip of the base counts bounds the drift at the
    # quantization step size; argmax agreement is the serving-level bar.
    assert np.mean(np.abs(got - want)) < 2e-3, np.mean(np.abs(got - want))
    agree = np.mean(got.argmax(-1) == want.argmax(-1))
    assert agree >= 0.9, agree


def test_refresh_requires_per_tenant_engine(served):
    cfg, params, archive = served
    head = SketchHead(cfg=_HEAD_CFG, backend="fused",
                      params=archive["tenant-0"])
    engine = make_engine(params, cfg, n_slots=1, max_seq=16, head=head)
    with pytest.raises(ValueError, match="per-tenant engine"):
        engine.refresh("tenant-0", jnp.zeros((1, cfg.d_model)),
                       alphas=jnp.zeros((1, cfg.vocab_size)))
    with pytest.raises(ValueError, match="no pending refresh"):
        spec = SketchHead(cfg=_HEAD_CFG, backend="fused")
        cache = HeadCache(archive.__getitem__, capacity=1)
        per_tenant = make_engine(params, cfg, n_slots=1, max_seq=16,
                                 head=spec, head_cache=cache)
        per_tenant.publish("tenant-0")
