"""Sharding rules: spec selection, divisibility fallbacks, cache layouts.

Uses abstract meshes (jax.sharding.Mesh over a numpy device array is only
constructible from real devices, so specs are checked through param_spec /
_fit_spec with a fake mesh object exposing axis_names + devices.shape)."""

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (_fit_spec, batch_spec, param_spec)


class FakeMesh:
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))
POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_fit_spec_drops_nondivisible():
    assert tuple(_fit_spec(P("model", None), (100, 8), MESH)) == (None, None)
    assert tuple(_fit_spec(P("model", None), (1600, 8), MESH)) == ("model", None)


def test_dense_ffn_specs():
    s = param_spec("periods/pos0/ffn/w_gate", (40, 5120, 13824), MESH, True)
    assert tuple(s) == (None, None, "model")
    s = param_spec("periods/pos0/ffn/w_down", (40, 13824, 5120), MESH, True)
    assert tuple(s) == (None, "model", None)


def test_moe_expert_specs_ep_vs_tp():
    # deepseek: 256 experts → EP over model + FSDP(d) over data
    s = param_spec("periods/pos0/ffn/w_gate", (58, 256, 7168, 2048), MESH, True)
    assert tuple(s) == (None, "model", "data", None)
    # mixtral: 8 experts < 16 → f-TP fallback + FSDP(d) over data
    s = param_spec("periods/pos0/ffn/w_gate", (32, 8, 4096, 14336), MESH, True)
    assert tuple(s) == (None, None, "data", "model")
    s = param_spec("periods/pos0/ffn/w_down", (32, 8, 14336, 4096), MESH, True)
    assert tuple(s) == (None, None, "model", "data")


def test_attention_specs():
    s = param_spec("periods/pos0/mixer/wq", (40, 5120, 5120), MESH, True)
    assert tuple(s) == (None, None, "model")
    s = param_spec("periods/pos0/mixer/wo", (40, 5120, 5120), MESH, True)
    assert tuple(s) == (None, "model", None)


def test_embed_head_specs():
    assert tuple(param_spec("embed", (100352, 5120), MESH, False)) == (
        "model", None)
    assert tuple(param_spec("head", (100352, 5120), MESH, False)) == (
        "model", None)


def test_norms_replicated():
    assert tuple(param_spec("periods/pos0/norm1", (40, 5120), MESH, True)
                 ) in ((None,), (None, None))


def test_batch_spec_divisibility():
    assert batch_spec(256, MESH) == "data"
    assert batch_spec(256, POD) == ("pod", "data")
    assert batch_spec(1, MESH) is None
    # 32 divides pod×data=32 on the pod mesh
    assert batch_spec(32, POD) == ("pod", "data")
    # 16 doesn't divide 32 → falls back to data(16)
    assert batch_spec(16, POD) == "data"


def test_cache_shardings_types():
    from repro.configs import get_config
    from repro.models.model import init_decode_cache
    from repro.sharding.rules import cache_shardings
    import jax.numpy as jnp

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("stablelm-12b", "deepseek-v3-671b", "rwkv6-1.6b",
                 "jamba-v0.1-52b"):
        cfg = get_config(arch, smoke=True)
        cache = jax.eval_shape(lambda: init_decode_cache(cfg, 2, 8))
        shardings = cache_shardings(cache, mesh, 2)
        # same tree structure, every leaf a NamedSharding
        jax.tree.map(lambda c, s: None, cache, shardings)
