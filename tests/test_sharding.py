"""Sharding rules: spec selection, divisibility fallbacks, cache layouts.

Uses abstract meshes (jax.sharding.Mesh over a numpy device array is only
constructible from real devices, so specs are checked through param_spec /
_fit_spec with a fake mesh object exposing axis_names + devices.shape)."""

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (_fit_spec, batch_spec, head_param_spec,
                                  head_rule_matches, param_spec)


class FakeMesh:
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))
POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_fit_spec_drops_nondivisible():
    assert tuple(_fit_spec(P("model", None), (100, 8), MESH)) == (None, None)
    assert tuple(_fit_spec(P("model", None), (1600, 8), MESH)) == ("model", None)


def test_dense_ffn_specs():
    s = param_spec("periods/pos0/ffn/w_gate", (40, 5120, 13824), MESH, True)
    assert tuple(s) == (None, None, "model")
    s = param_spec("periods/pos0/ffn/w_down", (40, 13824, 5120), MESH, True)
    assert tuple(s) == (None, "model", None)


def test_moe_expert_specs_ep_vs_tp():
    # deepseek: 256 experts → EP over model + FSDP(d) over data
    s = param_spec("periods/pos0/ffn/w_gate", (58, 256, 7168, 2048), MESH, True)
    assert tuple(s) == (None, "model", "data", None)
    # mixtral: 8 experts < 16 → f-TP fallback + FSDP(d) over data
    s = param_spec("periods/pos0/ffn/w_gate", (32, 8, 4096, 14336), MESH, True)
    assert tuple(s) == (None, None, "data", "model")
    s = param_spec("periods/pos0/ffn/w_down", (32, 8, 14336, 4096), MESH, True)
    assert tuple(s) == (None, None, "model", "data")


def test_attention_specs():
    s = param_spec("periods/pos0/mixer/wq", (40, 5120, 5120), MESH, True)
    assert tuple(s) == (None, None, "model")
    s = param_spec("periods/pos0/mixer/wo", (40, 5120, 5120), MESH, True)
    assert tuple(s) == (None, "model", None)


def test_embed_head_specs():
    assert tuple(param_spec("embed", (100352, 5120), MESH, False)) == (
        "model", None)
    assert tuple(param_spec("head", (100352, 5120), MESH, False)) == (
        "model", None)


def test_norms_replicated():
    assert tuple(param_spec("periods/pos0/norm1", (40, 5120), MESH, True)
                 ) in ((None,), (None, None))


def test_batch_spec_divisibility():
    assert batch_spec(256, MESH) == "data"
    assert batch_spec(256, POD) == ("pod", "data")
    assert batch_spec(1, MESH) is None
    # 32 divides pod×data=32 on the pod mesh
    assert batch_spec(32, POD) == ("pod", "data")
    # 16 doesn't divide 32 → falls back to data(16)
    assert batch_spec(16, POD) == "data"


def test_head_rules_cover_sketch_tree_exactly_once():
    """Every leaf of the frozen sketch-head tree matches exactly ONE head
    rule — no overlap ambiguity, and no leaf silently falling through to
    the replicate-everything default."""
    import jax.numpy as jnp
    from repro.core.sketch_lm_head import freeze_head
    from repro.models.config import SketchHeadConfig
    from repro.sharding.rules import _path_str

    cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=2, proj_dim=16,
                           bandwidth=2.0)
    head = jax.eval_shape(
        lambda: freeze_head(
            jax.random.PRNGKey(0),
            {"points": jnp.zeros((64, cfg.proj_dim)),
             "alphas": jnp.zeros((64, 128)),
             "proj": jnp.zeros((48, cfg.proj_dim))}, cfg))
    leaves = jax.tree_util.tree_flatten_with_path(head)[0]
    assert len(leaves) == 4
    for path, leaf in leaves:
        matches = head_rule_matches(_path_str(path))
        assert len(matches) == 1, (path, matches)


def test_head_param_specs_shard_count_arrays_over_model():
    # (L, R, V) count arrays: model on the repetition axis when it divides.
    assert tuple(head_param_spec("array", (32, 8, 256), MESH)) == (
        "model", None, None)
    # Non-divisible L falls back to replication rather than crashing.
    assert tuple(head_param_spec("array", (10, 8, 256), MESH)) == (
        None, None, None)
    # Hash params replicate (KB-scale; shard_map slices rows on the fly).
    assert tuple(head_param_spec("proj", (64, 16), MESH)) == (None, None)
    assert tuple(head_param_spec("w", (32, 2, 16), MESH)) == (
        None, None, None)
    assert tuple(head_param_spec("b", (32, 2), MESH)) == (None, None)
    # Unknown leaves of third-party heads replicate.
    assert tuple(head_param_spec("extra_state", (8, 8), MESH)) == (None, None)


def test_head_count_arrays_not_silently_replicated():
    """The array rule must actually fire — a regression here would leave
    every shard holding the full (L, R, V) tensor and the psum path dead."""
    spec = head_param_spec("array", (64, 16, 4096), MESH)
    used = {n for e in spec if e is not None
            for n in (e if isinstance(e, tuple) else (e,))}
    assert "model" in used


def test_cache_shardings_types():
    from repro.configs import get_config
    from repro.models.model import init_decode_cache
    from repro.sharding.rules import cache_shardings
    import jax.numpy as jnp

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("stablelm-12b", "deepseek-v3-671b", "rwkv6-1.6b",
                 "jamba-v0.1-52b"):
        cfg = get_config(arch, smoke=True)
        cache = jax.eval_shape(lambda: init_decode_cache(cfg, 2, 8))
        shardings = cache_shardings(cache, mesh, 2)
        # same tree structure, every leaf a NamedSharding
        jax.tree.map(lambda c, s: None, cache, shardings)
