"""End-to-end paper pipeline on a small tabular task:
teacher MLP → weighted-kernel student → Representer Sketch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DistillConfig, KernelModel, KernelModelConfig,
                        distill, mlp_flops, mlp_memory_params)
from repro.core.teacher import MLPConfig, accuracy, mlp_forward, train_mlp
from repro.data.tabular import DATASETS, make_dataset


@pytest.fixture(scope="module")
def pipeline():
    spec = DATASETS["skin"]
    xtr, ytr, xte, yte = make_dataset(spec, seed=1)
    xtr, ytr = xtr[:4000], ytr[:4000]
    xte, yte = xte[:1000], yte[:1000]
    key = jax.random.PRNGKey(0)
    mlp_cfg = MLPConfig(in_dim=spec.n_features, hidden=(64, 32), out_dim=2)
    teacher, _ = train_mlp(key, mlp_cfg, jnp.asarray(xtr), jnp.asarray(ytr),
                           n_steps=800)
    model = KernelModel(KernelModelConfig(
        in_dim=spec.n_features, proj_dim=8, n_points=128, n_outputs=2,
        bandwidth=2.0, k=1))
    kparams, metrics = distill(
        jax.random.PRNGKey(1), lambda x: mlp_forward(teacher, x),
        jnp.asarray(xtr), model, DistillConfig(n_steps=1200, lr=5e-3))
    return spec, teacher, mlp_cfg, model, kparams, metrics, (xte, yte)


def test_teacher_learns(pipeline):
    spec, teacher, mlp_cfg, *_, (xte, yte) = (
        pipeline[0], pipeline[1], pipeline[2], pipeline[3], pipeline[4],
        pipeline[5], pipeline[6])
    acc = accuracy(teacher, jnp.asarray(xte), jnp.asarray(yte))
    assert acc > 0.75, acc


def test_kernel_matches_teacher(pipeline):
    spec, teacher, _, model, kparams, metrics, (xte, yte) = pipeline
    t_out = mlp_forward(teacher, jnp.asarray(xte))
    k_out = model.apply(kparams, jnp.asarray(xte))
    t_acc = float(jnp.mean((jnp.argmax(t_out, -1) == jnp.asarray(yte))))
    k_acc = float(jnp.mean((jnp.argmax(k_out, -1) == jnp.asarray(yte))))
    assert k_acc > t_acc - 0.08, (t_acc, k_acc)


def test_sketch_matches_kernel(pipeline):
    spec, teacher, _, model, kparams, _, (xte, yte) = pipeline
    sk, state = model.freeze(jax.random.PRNGKey(2), kparams,
                             n_rows=800, n_buckets=spec.rs_R // 10 or 16)
    k_out = model.apply(kparams, jnp.asarray(xte))
    s_out = sk.query(state, model.transform(kparams, jnp.asarray(xte)))
    k_acc = float(jnp.mean((jnp.argmax(k_out, -1) == jnp.asarray(yte))))
    s_acc = float(jnp.mean((jnp.argmax(s_out, -1) == jnp.asarray(yte))))
    assert s_acc > k_acc - 0.10, (k_acc, s_acc)


def test_memory_and_flop_reduction_accounting(pipeline):
    spec, _, mlp_cfg, model, *_ = pipeline
    nn_mem = mlp_memory_params(mlp_cfg.layer_sizes)
    rs_mem = model.sketch_memory_params(n_rows=800, n_buckets=16)
    nn_flops = mlp_flops(mlp_cfg.layer_sizes)
    rs_flops = model.sketch_flops(n_rows=800, n_buckets=16)
    # Accounting must run and produce positive, comparable magnitudes; the
    # paper-scale reductions are reproduced in benchmarks/table1_repro.py.
    assert nn_mem > 0 and rs_mem > 0 and nn_flops > 0 and rs_flops > 0
