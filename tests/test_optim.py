"""Optimizer: AdamW convergence, schedule, int8 error-feedback compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWState, OptimizerConfig, adamw_update,
                               global_norm, init_adamw, lr_schedule)
from repro.optim.compress import (compress_grad_leaf, dequantize_int8,
                                  init_error_feedback, quantize_int8)


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_adamw(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        grads = {"w": 2 * (state.master["w"] - target)}
        params, state, m = adamw_update(grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.2)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] < lrs[10]                       # warmup
    assert lrs[10] == pytest.approx(1.0, rel=1e-3)
    assert lrs[100] == pytest.approx(0.1, rel=1e-2)  # cosine floor


def test_grad_clip_caps_update_norm():
    cfg = OptimizerConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0,
                          total_steps=10)
    params = {"w": jnp.zeros((4,))}
    state = init_adamw(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, state2, m = adamw_update(huge, state, cfg)
    assert float(m["grad_norm"]) > 1e5
    # after clipping, first moment is bounded by clip scale
    assert float(jnp.max(jnp.abs(state2.mu["w"]))) <= 0.2


def test_bf16_params_stay_bf16():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = init_adamw(params)
    new_params, state, _ = adamw_update({"w": jnp.ones((4,), jnp.bfloat16)},
                                        state, OptimizerConfig())
    assert new_params["w"].dtype == jnp.bfloat16
    assert state.master["w"].dtype == jnp.float32


def test_quantize_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1000,)) * 3.0
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_is_lossless_in_sum():
    """Σ_t dequant(q_t) == Σ_t g_t up to one residual: EF telescopes."""
    key = jax.random.PRNGKey(1)
    g_total = jnp.zeros((64,))
    sent_total = jnp.zeros((64,))
    err = jnp.zeros((64,))
    for t in range(50):
        g = jax.random.normal(jax.random.fold_in(key, t), (64,))
        q, scale, err = compress_grad_leaf(g, err)
        sent_total = sent_total + dequantize_int8(q, scale)
        g_total = g_total + g
    # residual carried in err is the only discrepancy
    np.testing.assert_allclose(np.asarray(sent_total + err),
                               np.asarray(g_total), rtol=1e-4, atol=1e-4)


def test_compressed_psum_single_device_mesh():
    """compressed_psum under shard_map on a 1-device mesh (degenerate axis)."""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.optim.compress import compressed_psum

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.linspace(-1, 1, 32)}
    e = init_error_feedback(g)

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
             check_rep=False)
    def f(gt, et):
        return compressed_psum(gt, et, "data")

    mean, new_e = f(g, e)
    np.testing.assert_allclose(np.asarray(mean["w"] + new_e["w"]),
                               np.asarray(g["w"]), atol=1e-5)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
