"""Checkpoint manager: async writes, manifest-gated completeness, restart."""

import json
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, tree):
    cm = CheckpointManager(tmp_path)
    cm.save(10, tree, blocking=True)
    restored, step = cm.restore(tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_incomplete_step_is_ignored(tmp_path, tree):
    cm = CheckpointManager(tmp_path)
    cm.save(10, tree, blocking=True)
    cm.save(20, tree, blocking=True)
    # Simulate a crash mid-write of step 30: shard exists, manifest doesn't.
    (tmp_path / "step_000000030").mkdir()
    np.savez(tmp_path / "step_000000030" / "shard_00000.npz",
             **{"x": np.zeros(3)})
    assert cm.latest_step() == 20
    _, step = cm.restore(tree)
    assert step == 20


def test_gc_keeps_last_n(tmp_path, tree):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    cm.wait()
    assert cm.complete_steps() == [3, 4]


def test_async_save_then_wait(tmp_path, tree):
    cm = CheckpointManager(tmp_path)
    for s in range(5):
        cm.save(s, tree)
    cm.wait()
    assert cm.latest_step() == 4


def test_restore_missing_raises(tmp_path, tree):
    cm = CheckpointManager(tmp_path / "empty")
    with pytest.raises(FileNotFoundError):
        cm.restore(tree)


def test_dtype_and_shape_validation(tmp_path, tree):
    cm = CheckpointManager(tmp_path)
    cm.save(1, tree, blocking=True)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((5,), jnp.int32)}}
    with pytest.raises(AssertionError):
        cm.restore(bad)
