"""Continuous-batching engine: static parity, slot isolation, slot reset,
and the serving-PRNG regression.

Parity grid: with synchronized arrivals and identical lengths the engine
must emit exactly the tokens of the static ``generate()`` path — for the
dense head and both sketch-head paths, across an attention arch (gemma2:
SWA ring + softcaps), a mamba hybrid (jamba: SSM + MoE), and an rwkv arch.
Scheduler invariants under random traffic live in
tests/test_engine_properties.py (hypothesis, slow).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head
from repro.launch.engine import ServeEngine, make_engine
from repro.launch.serve import generate
from repro.launch.steps import jitted_serve_fns
from repro.models.config import SketchHeadConfig
from repro.models.model import init_decode_cache, init_model

_ARCHS = ["gemma2-27b", "jamba-v0.1-52b", "rwkv6-1.6b"]
_HEADS = ["dense", "sketch-fused", "sketch-2kernel"]


def _direct_head(key, d_model: int, vocab: int, cfg: SketchHeadConfig):
    """Direct-construction frozen head (distillation quality is covered by
    tests/test_system.py; these tests exercise the engine plumbing)."""
    kp, ka, kj, kf = jax.random.split(key, 4)
    kparams = {
        "points": jax.random.normal(kp, (128, cfg.proj_dim)),
        "alphas": jax.random.normal(ka, (128, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    return freeze_head(kf, kparams, cfg)


def _head_for(cfg, head: str):
    """(sketch_head, sketch_cfg, fused) for one head flavor."""
    if head == "dense":
        return None, None, True
    head_cfg = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                                bandwidth=2.0)
    params = _direct_head(jax.random.PRNGKey(42), cfg.d_model,
                          cfg.vocab_size, head_cfg)
    return params, head_cfg, head == "sketch-fused"


@pytest.mark.parametrize("head", _HEADS)
@pytest.mark.parametrize("arch", _ARCHS)
def test_engine_matches_static_generate(arch, head):
    """Synchronized arrivals + identical lengths ⇒ engine tokens == generate."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sketch_head, sketch_cfg, fused = _head_for(cfg, head)
    b, p, g = 2, 5, 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                                 cfg.vocab_size)
    expected = np.asarray(generate(
        params, cfg, prompts, g, sketch_head_params=sketch_head,
        sketch_cfg=sketch_cfg, fused=fused))
    engine = make_engine(params, cfg, n_slots=b, max_seq=p + g,
                         sketch_head=sketch_head, sketch_cfg=sketch_cfg,
                         fused=fused)
    rids = [engine.submit(np.asarray(prompts[i]), g) for i in range(b)]
    out = engine.run()
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(np.asarray(out[rid]), expected[i, p:])
    assert engine.stats["admitted"] == engine.stats["retired"] == b
    assert engine.slot_utilization == 1.0  # no slot ever idles in lockstep


def test_engine_staggered_arrivals_match_solo_generate():
    """Recycled slots + per-slot positions: each request of a staggered,
    mixed-length stream must emit exactly its own solo-generate tokens."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = make_engine(params, cfg, n_slots=2, max_seq=16)
    stream = [(4, 6, 0), (6, 3, 0), (5, 8, 2), (4, 2, 5)]
    reqs = []
    for i, (plen, gen, arrival) in enumerate(stream):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(10 + i), (plen,), 0, cfg.vocab_size))
        reqs.append((engine.submit(prompt, gen, arrival=arrival),
                     prompt, gen))
    out = engine.run()
    for rid, prompt, gen in reqs:
        solo = np.asarray(generate(params, cfg, jnp.asarray(prompt)[None],
                                   gen))[0, len(prompt):]
        np.testing.assert_array_equal(np.asarray(out[rid]), solo)
    # 4 requests over 2 slots: retirement must have recycled slots.
    assert engine.stats["admitted"] == 4
    assert engine.sched.n_free == 2


@pytest.mark.parametrize("arch,plen", [
    ("gemma2-27b", 12),       # SWA ring wraps during prefill (window=8)
    ("jamba-v0.1-52b", 6),    # mamba state decay + MoE routing
])
def test_slot_insert_leaves_other_slots_bitwise_unchanged(arch, plen):
    """Admitting into a free slot while others are mid-decode must not
    perturb the other slots' next-step logits by a single bit (catches
    masking bugs in the SWA ring rebuild and mamba state decay)."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prefill, decode, insert, _ = jitted_serve_fns(cfg)
    max_seq = plen + 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, plen), 0,
                                 cfg.vocab_size)
    logits, filled = prefill(params, prompts,
                             cache=init_decode_cache(cfg, 2, max_seq))
    pool = insert(init_decode_cache(cfg, 3, max_seq), filled,
                  jnp.asarray([0, 1]))
    tok = jnp.concatenate([jnp.argmax(logits, -1).astype(jnp.int32),
                           jnp.zeros((1,), jnp.int32)])[:, None]
    pos = jnp.asarray([plen, plen, 0], jnp.int32)
    partial = jnp.asarray([True, True, False])
    # One decode step mid-stream, then branch: with vs without an admission.
    # decode/insert donate their cache argument (DESIGN.md §10), so each
    # branch gets its own copy of the shared mid-stream pool.
    l1, pool = decode(params, pool, tok, pos, active=partial)
    tok = jnp.concatenate([jnp.argmax(l1[:2], -1).astype(jnp.int32),
                           jnp.zeros((1,), jnp.int32)])[:, None]
    pos = jnp.asarray([plen + 1, plen + 1, 0], jnp.int32)

    l_a, _ = decode(params, jax.tree.map(jnp.copy, pool), tok, pos,
                    active=partial)

    new_prompt = jax.random.randint(jax.random.PRNGKey(2), (1, plen), 0,
                                    cfg.vocab_size)
    nl, nfilled = prefill(params, new_prompt,
                          cache=init_decode_cache(cfg, 1, max_seq))
    pool_b = insert(pool, nfilled, jnp.asarray([2]))
    tok_b = tok.at[2, 0].set(jnp.argmax(nl[0], -1).astype(jnp.int32))
    pos_b = pos.at[2].set(plen)
    l_b, _ = decode(params, pool_b, tok_b, pos_b,
                    active=jnp.asarray([True, True, True]))
    np.testing.assert_array_equal(np.asarray(l_a[:2]), np.asarray(l_b[:2]))


@pytest.mark.parametrize("arch", _ARCHS)
def test_retired_slots_reset_to_fresh_cache(arch):
    """After every request retires, the recycled pool must be bitwise
    identical to a freshly initialized one (slot_reset on retirement)."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = make_engine(params, cfg, n_slots=2, max_seq=10)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (6,), 0,
                                           cfg.vocab_size))
    engine.submit(prompt, 4)
    engine.run()
    fresh = init_decode_cache(cfg, 2, 10)
    for got, want in zip(jax.tree.leaves(engine.pool),
                         jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class _CounterBackend:
    """Numpy fake (mirrors test_engine_properties.FakeBackend): each slot's
    "cache" is a counter, the emitted token is the (modded) counter — so a
    request's stream has the closed form ``(last_prompt_tok + 1 + i) % V``
    and 1k-request traces run without a model in the loop."""

    vocab_size = 17

    def init_pool(self, n_slots, max_seq):
        return np.zeros(n_slots, np.int64)

    def prefill(self, prompts, max_seq):
        prompts = np.asarray(prompts)
        state = prompts[:, -1].astype(np.int64) + 1
        logits = np.zeros((prompts.shape[0], self.vocab_size), np.float32)
        logits[np.arange(len(state)), state % self.vocab_size] = 1.0
        return logits, state

    def insert(self, pool, filled, slots):
        pool = pool.copy()
        pool[np.asarray(slots)] = filled
        return pool

    def reset(self, pool, slots):
        pool = pool.copy()
        pool[np.asarray(slots)] = 0
        return pool

    def decode(self, pool, tokens, pos, active):
        nxt = (pool + 1) % self.vocab_size
        logits = np.zeros((len(nxt), self.vocab_size), np.float32)
        logits[np.arange(len(nxt)), nxt] = 1.0
        return logits, np.where(active, pool + 1, pool)


def test_request_queue_orders_1k_trace_fifo_on_ties():
    """Regression for the O(n²) queue: ``bisect.insort`` + ``list.pop(0)``
    became a heap.  Semantics pinned on a 1k-request trace with heavy
    arrival ties: pops come out arrival-sorted, submission order preserved
    within an arrival tick (the old insort-right behavior)."""
    import itertools

    from repro.launch.engine import Request, RequestQueue

    rng = np.random.default_rng(0)
    arrivals = rng.integers(0, 40, 1000)
    q = RequestQueue()
    for rid, a in enumerate(arrivals):
        q.push(Request(rid, np.zeros(1, np.int32), 1, int(a)))
    assert len(q) == 1000 and q.peek().arrival == int(arrivals.min())
    # The legacy list-style views agree with pop order (and slices, which
    # would silently leak raw heap tuples, are rejected).
    snapshot = list(q)
    assert q[0] is snapshot[0] and q[-1] is snapshot[-1]
    with pytest.raises(TypeError):
        q[:2]
    order = [q.pop() for _ in range(len(q))]
    assert not q
    assert [r.rid for r in snapshot] == [r.rid for r in order]
    assert [r.arrival for r in order] == sorted(arrivals.tolist())
    for _, group in itertools.groupby(order, key=lambda r: r.arrival):
        rids = [r.rid for r in group]
        assert rids == sorted(rids), "FIFO tie-break broken"


@pytest.mark.parametrize("decode_chunk", [1, 4])
def test_engine_drains_1k_request_trace(decode_chunk):
    """A 1k-request arrival stream through the real scheduler (numpy fake
    backend): every request retires exactly once with its exact stream —
    at the per-token tick and under chunked megastep ticks (the emulated
    megastep path backends without a fused one fall back to)."""
    engine = ServeEngine(_CounterBackend(), n_slots=4, max_seq=16,
                         decode_chunk=decode_chunk)
    rng = np.random.default_rng(1)
    reqs = []
    for _ in range(1000):
        last = int(rng.integers(0, 17))
        gen = int(rng.integers(1, 6))
        arrival = int(rng.integers(0, 3000))
        rid = engine.submit(np.full(2, last, np.int32), gen, arrival=arrival)
        reqs.append((rid, last, gen))
    finished = engine.run()
    assert engine.stats["admitted"] == engine.stats["retired"] == 1000
    for rid, last, gen in reqs:
        assert finished[rid] == [(last + 1 + i) % 17 for i in range(gen)]


def test_generate_sampling_seeded():
    """Regression for the serving PRNG: sampling used to rebuild
    ``PRNGKey(t)`` from the step index — one fixed stream for every run and
    every seed.  Now one seed is reproducible and seeds differ."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    a1 = np.asarray(generate(params, cfg, prompts, 8, greedy=False, seed=0))
    a2 = np.asarray(generate(params, cfg, prompts, 8, greedy=False, seed=0))
    b = np.asarray(generate(params, cfg, prompts, 8, greedy=False, seed=1))
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1[:, 4:], b[:, 4:])


def test_engine_sampling_seeded():
    """The engine's non-greedy decode threads the same seed discipline."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4,), 0,
                                           cfg.vocab_size))

    def run(seed):
        engine = make_engine(params, cfg, n_slots=2, max_seq=12,
                             greedy=False, seed=seed)
        rid = engine.submit(prompt, 8)
        return engine.run()[rid]

    assert run(0) == run(0)
    assert run(0) != run(1)
