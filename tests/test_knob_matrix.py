"""Cross-feature knob matrix (DESIGN.md §14 acceptance): every pairwise
combination of the serving knobs

    paged · spec_decode · decode_chunk>1 · quant · mesh · per-tenant

either composes **bitwise-correctly** or raises the documented
``ValueError`` — never a silent wrong answer.

Compose contract per pair: the knobs that are bitwise-transparent by
design (paged, decode_chunk, per-tenant-with-the-same-head; spec_decode
emits the dense stream) must not change the token stream of the knobs
that aren't (quant changes logits, mesh changes the partitioning).  So
each compose test compares the pair's stream against the reference run
holding only the logit-affecting knob(s) of that pair.  Mesh pairs run
under the forced-CPU multi-device jobs and skip elsewhere.
"""

import jax
import numpy as np
import pytest

from repro.api import DenseHead, HeadCache, Sampler, SketchHead, \
    SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import freeze_head
from repro.launch.engine import ServeEngine, make_engine
from repro.models.model import init_model

_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)
_MESH_REASON = "needs XLA_FLAGS=--xla_force_host_platform_device_count=8"

needs_mesh = pytest.mark.skipif(len(jax.devices()) < 8, reason=_MESH_REASON)


@pytest.fixture(scope="module")
def world():
    """(cfg, params, f32 head params, int8 head params) — one smoke arch;
    the matrix exercises knob plumbing, not architectures."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    kp, ka, kj = jax.random.split(jax.random.PRNGKey(3), 3)
    kparams = {
        "points": jax.random.normal(kp, (128, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(kj, (cfg.d_model, _HEAD_CFG.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    f32 = freeze_head(jax.random.PRNGKey(42), kparams, _HEAD_CFG)
    int8 = freeze_head(jax.random.PRNGKey(42), kparams, _HEAD_CFG,
                       quant="int8")
    return cfg, params, f32, int8


def _head(world, quant):
    _, _, f32, int8 = world
    return (SketchHead(cfg=_HEAD_CFG, backend="fused", quant="int8",
                       params=int8) if quant
            else SketchHead(cfg=_HEAD_CFG, backend="fused", params=f32))


def _prompts(cfg, n=2, plen=4):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(30 + i),
                                          (plen,), 0, cfg.vocab_size))
            for i in range(n)]


def _serve(world, *, quant=False, tenant=False, mesh=None, gen=4,
           **engine_kw):
    """One tiny workload through an engine with the given knobs; returns
    the per-request streams in submission order."""
    cfg, params, f32, int8 = world
    head = _head(world, quant)
    if mesh is not None:
        from repro.launch.mesh import place_serving_state
        params, head = place_serving_state(params, head, mesh)
    head_cache = None
    if tenant:
        # One tenant whose bank holds exactly the reference head's params:
        # the per-tenant gather must reproduce the plain engine bitwise.
        archive = {"tenant-0": int8 if quant else f32}
        head_cache = HeadCache(archive.__getitem__, capacity=1)
        head = SketchHead(cfg=_HEAD_CFG, backend="fused",
                          quant="int8" if quant else None)
    prompts = _prompts(cfg)
    engine = make_engine(params, cfg, n_slots=len(prompts),
                         max_seq=len(prompts[0]) + gen, head=head,
                         mesh=mesh, head_cache=head_cache, **engine_kw)
    rids = [engine.submit(p, gen, tenant="tenant-0" if tenant else None)
            for p in prompts]
    out = engine.run()
    return [out[r] for r in rids]


# ------------------------------------------------------- documented errors

def _spec_engine_kw(k=2):
    return dict(spec_decode=k, sampler=Sampler(seed=0))


@pytest.mark.parametrize("kw,msg", [
    (dict(spec_decode=2, decode_chunk=2, sampler=Sampler(seed=0)),
     "spec_decode and decode_chunk > 1 are mutually exclusive"),
    (dict(paged=True, decode_chunk=2, sampler=Sampler(seed=0)),
     "decode_chunk > 1 is not supported yet"),
    (dict(paged=True, spec_decode=2, sampler=Sampler(seed=0)),
     "spec_decode are mutually exclusive"),
], ids=["spec+chunk", "paged+chunk", "paged+spec"])
def test_pair_raises_documented_error(world, kw, msg):
    cfg, params, f32, _ = world
    head = SketchHead(cfg=_HEAD_CFG, backend="fused", params=f32)
    with pytest.raises(ValueError, match=msg):
        make_engine(params, cfg, n_slots=2, max_seq=16, head=head, **kw)


def test_spec_plus_tenant_raises(world):
    cfg, params, f32, _ = world
    cache = HeadCache({"tenant-0": f32}.__getitem__, capacity=1)
    spec = SketchHead(cfg=_HEAD_CFG, backend="fused")
    with pytest.raises(ValueError,
                       match="spec_decode and per-tenant heads are mutually "
                             "exclusive"):
        make_engine(params, cfg, n_slots=2, max_seq=16, head=spec,
                    head_cache=cache, **_spec_engine_kw())
    # The same guard sits in the ServeEngine ctor for hand-built backends.
    with pytest.raises(ValueError, match="per-tenant heads"):
        ServeEngine(object(), 2, 16, head_cache=cache, spec_decode=2,
                    sampler=Sampler(seed=0))


def test_tenant_submit_contract(world):
    cfg, params, f32, _ = world
    cache = HeadCache({"tenant-0": f32}.__getitem__, capacity=1)
    spec = SketchHead(cfg=_HEAD_CFG, backend="fused")
    engine = make_engine(params, cfg, n_slots=1, max_seq=16, head=spec,
                         head_cache=cache)
    with pytest.raises(ValueError, match="every submit needs tenant="):
        engine.submit(_prompts(cfg, 1)[0], 2)
    plain = make_engine(params, cfg, n_slots=1, max_seq=16,
                        head=SketchHead(cfg=_HEAD_CFG, backend="fused",
                                        params=f32))
    with pytest.raises(ValueError, match="needs a per-tenant engine"):
        plain.submit(_prompts(cfg, 1)[0], 2, tenant="tenant-0")


# ----------------------------------------------------------- compose pairs

@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("knob", ["paged", "chunk", "tenant"],
                         ids=["paged", "chunk2", "tenant"])
def test_transparent_knob_composes_with_quant(world, knob, quant):
    """paged / decode_chunk=2 / per-tenant must leave the (possibly
    quantized) stream bitwise unchanged — this covers the quant×paged,
    quant×chunk, quant×tenant pairs and the single-knob rows."""
    reference = _serve(world, quant=quant)
    kw = {"paged": dict(paged=True, page_size=4),
          "chunk": dict(decode_chunk=2, sampler=Sampler(seed=0)),
          "tenant": dict(tenant=True)}[knob]
    got = _serve(world, quant=quant, **kw)
    for a, b in zip(got, reference):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_composes_with_tenant(world):
    """paged×tenant: the paged pool pages caches, the HeadCache pages
    heads — together they must still emit the plain engine's stream."""
    reference = _serve(world)
    got = _serve(world, tenant=True, paged=True, page_size=4)
    for a, b in zip(got, reference):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_composes_with_tenant(world):
    """chunk×tenant: the per-slot tenant gather rides inside the K-token
    megastep scan — stream bitwise equal to the per-token tenant tick."""
    reference = _serve(world, tenant=True)
    got = _serve(world, tenant=True, decode_chunk=2,
                 sampler=Sampler(seed=0))
    for a, b in zip(got, reference):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spec_composes_with_quant(world):
    """spec×quant: speculative decode through an int8 draft head still
    emits the *dense* stream bitwise (acceptance may change, tokens not)."""
    cfg, params, f32, int8 = world
    prompts = _prompts(cfg)
    sampler = Sampler(seed=0)
    dense = make_engine(params, cfg, n_slots=2, max_seq=16,
                        head=DenseHead(), sampler=sampler)
    rids = [dense.submit(p, 4) for p in prompts]
    want = dense.run()
    spec = make_engine(params, cfg, n_slots=2, max_seq=16,
                       head=_head(world, True), sampler=sampler,
                       spec_decode=2)
    rids2 = [spec.submit(p, 4) for p in prompts]
    got = spec.run()
    for a, b in zip(rids2, rids):
        np.testing.assert_array_equal(np.asarray(got[a]),
                                      np.asarray(want[b]))


# -------------------------------------------------------------- mesh pairs

@needs_mesh
@pytest.mark.parametrize("knob", ["paged", "chunk", "tenant", "quant"])
def test_knob_composes_with_mesh(world, knob):
    """mesh×{paged, chunk, tenant, quant}: each knob on the 4×2 mesh must
    reproduce the stream of its own on-mesh reference (the bf16 backbone
    is not bitwise-stable *across* partitionings, so every comparison
    stays on the mesh — DESIGN.md §9)."""
    from repro.launch.mesh import parse_mesh

    mesh = parse_mesh("4x2")
    if knob == "quant":
        # quant×mesh: both knobs affect numerics; the invariant is the
        # engine-vs-engine determinism of the pair itself.
        a = _serve(world, quant=True, mesh=mesh)
        b = _serve(world, quant=True, mesh=mesh)
    else:
        b = _serve(world, mesh=mesh)
        kw = {"paged": dict(paged=True, page_size=4),
              "chunk": dict(decode_chunk=2, sampler=Sampler(seed=0)),
              "tenant": dict(tenant=True)}[knob]
        a = _serve(world, mesh=mesh, **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@needs_mesh
def test_spec_composes_with_mesh(world):
    """mesh×spec: the on-mesh speculative engine emits the on-mesh dense
    engine's stream bitwise."""
    from repro.launch.mesh import parse_mesh, place_serving_state

    cfg, params, f32, _ = world
    mesh = parse_mesh("4x2")
    sampler = Sampler(seed=0)
    head = SketchHead(cfg=_HEAD_CFG, backend="fused", params=f32)
    placed, head = place_serving_state(params, head, mesh)
    prompts = _prompts(cfg)
    dense = make_engine(placed, cfg, n_slots=2, max_seq=16,
                        head=DenseHead(), sampler=sampler, mesh=mesh)
    rids = [dense.submit(p, 4) for p in prompts]
    want = dense.run()
    spec = make_engine(placed, cfg, n_slots=2, max_seq=16, head=head,
                       sampler=sampler, spec_decode=2, mesh=mesh)
    rids2 = [spec.submit(p, 4) for p in prompts]
    got = spec.run()
    for a, b in zip(rids2, rids):
        np.testing.assert_array_equal(np.asarray(got[a]),
                                      np.asarray(want[b]))
