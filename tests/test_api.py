"""The repro.api surface (DESIGN.md §8): golden parity against the
pre-redesign call paths, LogitHead registry round-trips, Sampler
determinism, deprecation shims, eos_id early stop, and kernel-backend
dispatch."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (LM, DenseHead, Sampler, SketchHead, SketchHeadConfig,
                       load_head)
from repro.configs import get_config
from repro.core.sketch_lm_head import apply_head, freeze_head
from repro.kernels import registry
from repro.launch.engine import make_engine
from repro.launch.serve import generate
from repro.models.model import init_model

_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)


def _direct_head_params(key, d_model: int, vocab: int,
                        cfg: SketchHeadConfig) -> dict:
    """Direct-construction frozen head (distillation quality is covered by
    tests/test_system.py; these tests exercise the API plumbing)."""
    kp, ka, kj, kf = jax.random.split(key, 4)
    kparams = {
        "points": jax.random.normal(kp, (128, cfg.proj_dim)),
        "alphas": jax.random.normal(ka, (128, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    return freeze_head(kf, kparams, cfg)


@pytest.fixture(scope="module")
def served():
    """(cfg, params, frozen head params) for one smoke arch."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_params = _direct_head_params(jax.random.PRNGKey(42), cfg.d_model,
                                      cfg.vocab_size, _HEAD_CFG)
    return cfg, params, head_params


def _head_for(kind: str, head_params) -> "DenseHead | SketchHead":
    if kind == "dense":
        return DenseHead()
    backend = {"sketch-fused": "fused", "sketch-2kernel": "two_kernel"}[kind]
    return SketchHead(cfg=_HEAD_CFG, backend=backend, params=head_params)


def _legacy_kwargs(kind: str, head_params) -> dict:
    if kind == "dense":
        return {}
    return {"sketch_head_params": head_params, "sketch_cfg": _HEAD_CFG,
            "fused": kind == "sketch-fused"}


# --------------------------------------------------------------------------
# golden parity: new facade == pre-redesign call paths, bitwise
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "sketch-fused", "sketch-2kernel"])
def test_lm_generate_matches_legacy_static_path(served, kind):
    cfg, params, head_params = served
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                 cfg.vocab_size)
    legacy_kw = _legacy_kwargs(kind, head_params)
    if legacy_kw:
        with pytest.warns(DeprecationWarning):
            legacy = np.asarray(generate(params, cfg, prompts, 4, **legacy_kw))
    else:
        legacy = np.asarray(generate(params, cfg, prompts, 4))
    lm = LM(params, cfg, _head_for(kind, head_params))
    np.testing.assert_array_equal(np.asarray(lm.generate(prompts, 4)), legacy)


@pytest.mark.parametrize("kind", ["dense", "sketch-fused", "sketch-2kernel"])
def test_lm_serve_matches_legacy_engine_path(served, kind):
    cfg, params, head_params = served
    b, p, g = 2, 5, 4
    prompts = jax.random.randint(jax.random.PRNGKey(2), (b, p), 0,
                                 cfg.vocab_size)
    legacy_kw = _legacy_kwargs(kind, head_params)
    if legacy_kw:
        with pytest.warns(DeprecationWarning):
            engine = make_engine(params, cfg, n_slots=b, max_seq=p + g,
                                 sketch_head=legacy_kw["sketch_head_params"],
                                 sketch_cfg=legacy_kw["sketch_cfg"],
                                 fused=legacy_kw["fused"])
    else:
        engine = make_engine(params, cfg, n_slots=b, max_seq=p + g)
    rids = [engine.submit(np.asarray(prompts[i]), g) for i in range(b)]
    legacy = engine.run()

    lm = LM(params, cfg, _head_for(kind, head_params))
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b)
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      np.asarray(legacy[rid]))


# --------------------------------------------------------------------------
# head registry round-trip
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["fused", "two_kernel", "ref"])
def test_head_save_load_roundtrips_kind_and_backend(tmp_path, backend):
    head_params = _direct_head_params(jax.random.PRNGKey(3), 24, 64, _HEAD_CFG)
    head = SketchHead(cfg=_HEAD_CFG, backend=backend, params=head_params)
    head.save(tmp_path / "head.npz")
    loaded = load_head(tmp_path / "head.npz")
    assert isinstance(loaded, SketchHead)
    assert loaded.kind == "sketch"
    assert loaded.backend == backend
    assert loaded.cfg == _HEAD_CFG
    for k in head_params:
        np.testing.assert_array_equal(np.asarray(loaded.params[k]),
                                      np.asarray(head_params[k]))
    # The spec (hash/eq) ignores the arrays, so loaded == original.
    assert loaded == head.without_params().with_params(loaded.params)


def test_legacy_archives_load_as_fused_sketch(tmp_path):
    """Heads saved before the registry metadata existed still load."""
    from repro.core.sketch_lm_head import save_head as core_save

    head_params = _direct_head_params(jax.random.PRNGKey(4), 24, 64, _HEAD_CFG)
    path = tmp_path / "legacy.npz"
    core_save(path, head_params, _HEAD_CFG)
    data = dict(np.load(path))
    for k in ("meta_kind", "meta_backend"):  # simulate a pre-metadata file
        data.pop(k)
    np.savez(path, **data)
    loaded = load_head(path)
    assert isinstance(loaded, SketchHead) and loaded.backend == "fused"


def test_unknown_sketch_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        SketchHead(cfg=_HEAD_CFG, backend="warp")


def test_head_specs_are_hashable_jit_keys(served):
    """Same spec (any params) must hit the same jitted-step memo entry."""
    from repro.launch.steps import jitted_serve_fns

    cfg, _, head_params = served
    a = jitted_serve_fns(cfg, SketchHead(cfg=_HEAD_CFG, backend="fused",
                                         params=head_params))
    b = jitted_serve_fns(cfg, SketchHead(cfg=_HEAD_CFG, backend="fused"))
    c = jitted_serve_fns(cfg, SketchHead(cfg=_HEAD_CFG, backend="two_kernel"))
    assert a is b
    assert a is not c
    assert jitted_serve_fns(cfg) is jitted_serve_fns(cfg, DenseHead())


# --------------------------------------------------------------------------
# Sampler
# --------------------------------------------------------------------------

def test_sampler_deterministic_under_fixed_seed(served):
    cfg, params, _ = served
    lm = LM(params, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 4), 0,
                                 cfg.vocab_size)
    s = Sampler(temperature=1.0, seed=7)
    a = np.asarray(lm.generate(prompts, 6, sampler=s))
    b = np.asarray(lm.generate(prompts, 6, sampler=s))
    c = np.asarray(lm.generate(prompts, 6,
                               sampler=Sampler(temperature=1.0, seed=8)))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a[:, 4:], c[:, 4:])


def test_sampler_filters_degenerate_to_greedy():
    """top_k=1 and a tiny nucleus both collapse sampling onto the argmax."""
    logits = jax.random.normal(jax.random.PRNGKey(6), (5, 64))
    want = np.asarray(jnp.argmax(logits, -1))
    for s in (Sampler(temperature=1.0, top_k=1, seed=0),
              Sampler(temperature=1.0, top_p=1e-6, seed=0)):
        _, got = s.sample(s.init_key(), logits)
        np.testing.assert_array_equal(np.asarray(got), want)
    _, greedy = Sampler().sample(Sampler().init_key(), logits)
    np.testing.assert_array_equal(np.asarray(greedy), want)


def test_sampler_top_p_keeps_boundary_ties():
    """A kept token tied with the largest cut logit must survive the
    nucleus filter — masking ties too used to empty the whole row, making
    sampling deterministically return token 0."""
    logits = jnp.asarray([[5.0, 5.0, 3.0, 1.0]])
    s = Sampler(temperature=1.0, top_p=0.3, seed=0)
    key = s.init_key()
    seen = set()
    for _ in range(8):
        key, tok = s.sample(key, logits)
        seen.add(int(tok[0]))
    assert seen <= {0, 1}     # the nucleus is the tied pair …
    assert len(seen) == 2     # … and both of its members stay reachable


def test_sampler_validation():
    with pytest.raises(ValueError):
        Sampler(temperature=-1.0)
    with pytest.raises(ValueError):
        Sampler(top_p=0.0)
    with pytest.raises(ValueError):
        Sampler(top_k=-1)


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

def test_apply_head_fused_kwarg_warns_and_forwards():
    head_params = _direct_head_params(jax.random.PRNGKey(7), 24, 64, _HEAD_CFG)
    hidden = jax.random.normal(jax.random.PRNGKey(8), (3, 24))
    with pytest.warns(DeprecationWarning):
        legacy = apply_head(head_params, hidden, _HEAD_CFG, fused=True)
    np.testing.assert_array_equal(
        np.asarray(legacy),
        np.asarray(apply_head(head_params, hidden, _HEAD_CFG,
                              backend="fused")))
    with pytest.warns(DeprecationWarning):
        legacy_2k = apply_head(head_params, hidden, _HEAD_CFG, fused=False,
                               use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(legacy_2k),
        np.asarray(apply_head(head_params, hidden, _HEAD_CFG, backend="ref")),
        rtol=1e-6, atol=1e-6)


def test_generate_legacy_kwargs_warn(served):
    cfg, params, head_params = served
    prompts = jax.random.randint(jax.random.PRNGKey(9), (1, 4), 0,
                                 cfg.vocab_size)
    with pytest.warns(DeprecationWarning):
        generate(params, cfg, prompts, 2, greedy=True)
    with pytest.warns(DeprecationWarning):
        generate(params, cfg, prompts, 2, sketch_head_params=head_params,
                 sketch_cfg=_HEAD_CFG, fused=False)


def test_make_engine_legacy_kwargs_warn(served):
    cfg, params, _ = served
    with pytest.warns(DeprecationWarning):
        make_engine(params, cfg, n_slots=1, max_seq=8, greedy=False, seed=3)


# --------------------------------------------------------------------------
# eos_id early stop (static generate == engine retirement discipline)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "sketch-fused"])
def test_generate_eos_early_stop_matches_engine(served, kind):
    cfg, params, head_params = served
    head = _head_for(kind, head_params)
    lm = LM(params, cfg, head)
    b, p, g = 2, 5, 8
    prompts = jax.random.randint(jax.random.PRNGKey(10), (b, p), 0,
                                 cfg.vocab_size)
    ref = np.asarray(lm.generate(prompts, g))          # no eos: full budget
    eos = int(ref[0, p + 2])                           # row 0 stops at step 2
    pad = -1

    tokens, stats = generate(params, cfg, prompts, g, head=head,
                             eos_id=eos, pad_id=pad, return_stats=True)
    tokens = np.asarray(tokens)
    assert tokens.shape == (b, p + g)
    for i in range(b):
        row_ref = ref[i, p:]
        hits = np.flatnonzero(row_ref == eos)
        n_live = (int(hits[0]) + 1) if hits.size else g
        # Tokens up to (and including) EOS match the unbounded run …
        np.testing.assert_array_equal(tokens[i, p:p + n_live],
                                      row_ref[:n_live])
        # … and everything past EOS is padding.
        assert (tokens[i, p + n_live:] == pad).all()
    # Finished sequences stop counting toward decode work: the loop ends as
    # soon as the slowest surviving row does.
    live = []
    for i in range(b):
        hits = np.flatnonzero(ref[i, p:] == eos)
        live.append((int(hits[0]) + 1) if hits.size else g)
    assert stats["decode_steps"] == min(max(live) - 1, g - 1)

    # Engine parity: per-request retirement produces the same sequences.
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b, eos_id=eos)
    for i in range(b):
        n_live = live[i]
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      ref[i, p:p + n_live])


def test_generate_eos_on_first_token_skips_decode_entirely(served):
    cfg, params, _ = served
    lm = LM(params, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(11), (1, 4), 0,
                                 cfg.vocab_size)
    ref = np.asarray(lm.generate(prompts, 4))
    eos = int(ref[0, 4])                               # the first new token
    tokens, stats = generate(params, cfg, prompts, 4, eos_id=eos,
                             pad_id=0, return_stats=True)
    assert stats["decode_steps"] == 0
    assert (np.asarray(tokens)[0, 5:] == 0).all()


# --------------------------------------------------------------------------
# kernel backend registry
# --------------------------------------------------------------------------

def test_registry_lists_all_op_packages():
    # Importing the ops modules registers them; the serving path has already
    # pulled most in, but import explicitly so the test stands alone.
    import repro.kernels.flash_attn.ops  # noqa: F401
    import repro.kernels.fused_decode.ops  # noqa: F401
    import repro.kernels.lsh_hash.ops  # noqa: F401
    import repro.kernels.race_query.ops  # noqa: F401
    import repro.kernels.race_update.ops  # noqa: F401
    import repro.kernels.sketch_head.ops  # noqa: F401

    assert set(registry.ops()) >= {"flash_attn", "fused_decode", "lsh_hash",
                                   "race_query", "race_update", "sketch_head"}
    for op in registry.ops():
        assert set(registry.backends(op)) == {"pallas", "ref"}


def test_registry_per_call_backend_matches_pallas():
    from repro.kernels.lsh_hash.ops import lsh_hash

    x = jax.random.normal(jax.random.PRNGKey(12), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(13), (3, 2, 8))
    b = jax.random.uniform(jax.random.PRNGKey(14), (3, 2))
    got_p = lsh_hash(x, w, b, bandwidth=1.0, n_buckets=8, backend="pallas")
    got_r = lsh_hash(x, w, b, bandwidth=1.0, n_buckets=8, backend="ref")
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_r))


def test_registry_env_and_override_dispatch(monkeypatch):
    import repro.kernels.lsh_hash.ops  # noqa: F401 — ensure registered

    monkeypatch.setenv(registry.ENV_VAR, "ref")
    assert registry.default_backend() == "ref"
    assert (registry.resolve("lsh_hash")
            is registry.resolve("lsh_hash", backend="ref"))
    try:
        registry.set_default_backend("pallas")  # override beats the env var
        assert registry.default_backend() == "pallas"
    finally:
        registry.set_default_backend(None)
    monkeypatch.delenv(registry.ENV_VAR)
    assert registry.default_backend() == "pallas"


def test_registry_rejects_unknown_names():
    import repro.kernels.lsh_hash.ops  # noqa: F401 — ensure registered

    with pytest.raises(KeyError):
        registry.resolve("warp_drive")
    with pytest.raises(ValueError):
        registry.resolve("lsh_hash", backend="cuda")
    with pytest.raises(ValueError):
        registry.set_default_backend("cuda")
