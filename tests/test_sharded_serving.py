"""Sharded serving parity (DESIGN.md §9): forced-CPU 8-device 4×2 mesh.

Runs only under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI multi-device job; see README); with fewer devices every test skips.
Asserts the acceptance bar of the sharded-serving redesign:

* the sharded head path matches the single-device head bitwise-modulo-psum
  (ref backend ~1e-7; pallas within float tolerance) on identical hiddens;
* ``LM.generate`` emits token streams identical to the single-device path
  for the dense head, and sharded serving is seed-deterministic;
* on the mesh, the engine (slot insert / per-slot decode / reset — the ops
  this redesign made sharding-preserving) produces token streams bitwise
  identical to the static ``LM.generate`` path for dense and sketch heads;
* the sketch count arrays are *actually* partitioned over ``model`` on the
  repetition axis (asserted via ``.sharding``), hash params replicated;
* the engine's slot pool keeps its cache shardings across
  insert / decode / reset instead of gathering to one device.

Why sketch streams are not compared across meshes: the bf16 backbone is
not bitwise-reproducible across different SPMD partitionings (one-ulp
bf16 rounding differences in TP partial sums), and the sketch head's
``floor(·/r)`` quantization turns those ulps into occasional discrete
bucket flips, i.e. O(1/L) logit changes — the dense head's spread-out
logits absorb the noise, near-tied sketch estimates occasionally flip an
argmax.  The single-vs-sharded *head* parity (given one hidden) and the
on-mesh engine-vs-static parity are the deterministic invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LM, Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import apply_head, freeze_head
from repro.launch.mesh import parse_mesh
from repro.models.model import init_model

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)


def _head_params(key, d_model, vocab, cfg=_HEAD_CFG):
    kp, ka, kj, kf = jax.random.split(key, 4)
    kparams = {
        "points": jax.random.normal(kp, (128, cfg.proj_dim)),
        "alphas": jax.random.normal(ka, (128, vocab)) * 0.01,
        "proj": jax.random.normal(kj, (d_model, cfg.proj_dim))
        / np.sqrt(d_model),
    }
    return freeze_head(kf, kparams, cfg)


@pytest.fixture(scope="module")
def mesh():
    return parse_mesh("4x2")


@pytest.fixture(scope="module", params=["rwkv6-1.6b", "gemma2-27b"])
def served(request):
    """(cfg, params, head params) for one smoke arch (state + KV families)."""
    cfg = get_config(request.param, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_params = _head_params(jax.random.PRNGKey(42), cfg.d_model,
                               cfg.vocab_size)
    return cfg, params, head_params


def _heads(head_params):
    return {
        "dense": None,
        "sketch-ref": SketchHead(cfg=_HEAD_CFG, backend="ref",
                                 params=head_params),
        "sketch-fused": SketchHead(cfg=_HEAD_CFG, backend="fused",
                                   params=head_params),
    }


# --------------------------------------------------------------------------
# token-stream parity
# --------------------------------------------------------------------------

def test_generate_dense_token_parity_vs_single_device(served, mesh):
    """Dense streams are identical on and off the 4×2 mesh (the margins of
    dense logits dominate SPMD bf16 rounding noise)."""
    cfg, params, _ = served
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                 cfg.vocab_size)
    lm1 = LM(params, cfg)
    base = np.asarray(lm1.generate(prompts, 5))
    sharded = np.asarray(lm1.with_mesh(mesh).generate(prompts, 5))
    np.testing.assert_array_equal(sharded, base)


@pytest.mark.parametrize("kind", ["dense", "sketch-ref", "sketch-fused"])
def test_sharded_generate_deterministic(served, mesh, kind):
    """Two sharded sampled runs with one seed reproduce bitwise."""
    cfg, params, head_params = served
    head = _heads(head_params)[kind]
    sampler = Sampler(temperature=0.8, top_k=8, seed=3)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 6), 0,
                                 cfg.vocab_size)
    lm = (LM(params, cfg) if head is None
          else LM(params, cfg, head)).with_mesh(mesh)
    a = np.asarray(lm.generate(prompts, 5, sampler=sampler))
    b = np.asarray(lm.generate(prompts, 5, sampler=sampler))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kind", ["dense", "sketch-ref", "sketch-fused"])
def test_engine_matches_generate_on_mesh(served, mesh, kind):
    """On the mesh, the engine's slot machinery (prefill-on-admit →
    slot_insert → per-slot decode → slot_reset, all sharding-preserving)
    reproduces the static ``generate`` streams bitwise."""
    cfg, params, head_params = served
    head = _heads(head_params)[kind]
    b, p, g = 4, 6, 5
    prompts = jax.random.randint(jax.random.PRNGKey(3), (b, p), 0,
                                 cfg.vocab_size)
    lm = (LM(params, cfg) if head is None
          else LM(params, cfg, head)).with_mesh(mesh)
    static = np.asarray(lm.generate(prompts, g))
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b)
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      static[i, p:])


def test_engine_staggered_matches_solo_on_mesh(served, mesh):
    """Staggered sharded-engine streams equal per-request solo generates on
    the same mesh (batch rows are independent under SPMD too)."""
    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="ref", params=head_params)
    lm = LM(params, cfg, head).with_mesh(mesh)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32),
             3 + (i % 3), i) for i in range(6)]
    finished = lm.serve(reqs, n_slots=4)
    for rid, (prompt, gen, _) in enumerate(reqs):
        solo = np.asarray(lm.generate(prompt[None], gen))
        np.testing.assert_array_equal(np.asarray(finished[rid]),
                                      solo[0, len(prompt):])


@pytest.mark.parametrize("kind", ["dense", "sketch-fused"])
def test_paged_engine_matches_contiguous_on_mesh(served, mesh, kind):
    """Paged serving ON the mesh (DESIGN.md §13): the page pool keeps the
    PR-4 cache sharding constraints (head/latent dims over ``model``, page
    and in-page axes replicated), so the gathered view feeds the same
    sharded decode executable and the streams — seeded, with prefix hits
    and COW traffic — replay the contiguous engine's bitwise."""
    cfg, params, head_params = served
    head = _heads(head_params)[kind]
    lm = (LM(params, cfg) if head is None
          else LM(params, cfg, head)).with_mesh(mesh)
    rng = np.random.default_rng(4)
    base = [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
            for plen in (5, 9, 5, 13)]
    reqs = [(base[int(rng.integers(0, len(base)))],
             int(rng.integers(2, 7)), i // 3) for i in range(12)]
    sampler = Sampler(temperature=1.0, seed=7)
    outs = {}
    for paged in (False, True):
        engine = lm.engine(4, 32, sampler=sampler, paged=paged,
                           page_size=4)
        for rid, (prompt, gen, arrival) in enumerate(reqs):
            engine.submit(prompt, gen, arrival=arrival, rid=rid)
        outs[paged] = engine.run()
        if paged:
            assert engine.stats["prefix_hits"] > 0
    assert outs[False] == outs[True]


@pytest.mark.parametrize("kind", ["sketch-ref", "sketch-fused"])
def test_spec_decode_matches_dense_on_mesh(served, mesh, kind):
    """Speculative self-decode ON the mesh (DESIGN.md §11): drafts run the
    sharded sketch-head path (count arrays over ``model``, one psum per
    step), the batched verify runs under the same constraint layout as the
    forward pass — and the emitted streams equal the pure dense streams on
    the same mesh, bitwise, static and engine, greedy and seeded, with the
    random head rejecting mid-block nearly every megastep."""
    cfg, params, head_params = served
    head = _heads(head_params)[kind]
    lm = LM(params, cfg, head).with_mesh(mesh)
    dense = LM(params, cfg).with_mesh(mesh)
    b, p, g = 4, 6, 5
    prompts = jax.random.randint(jax.random.PRNGKey(5), (b, p), 0,
                                 cfg.vocab_size)
    for sampler in (Sampler(), Sampler(temperature=0.9, top_k=12, seed=7)):
        base = np.asarray(dense.generate(prompts, g, sampler=sampler))
        for k in (1, 4):
            got = np.asarray(lm.generate(prompts, g, sampler=sampler,
                                         spec_decode=k))
            np.testing.assert_array_equal(
                got, base,
                err_msg=f"on-mesh spec_decode={k} diverged ({kind})")
    reqs = [(np.asarray(prompts[i]), g) for i in range(b)]
    ebase = dense.serve(reqs, n_slots=b)
    assert lm.serve(reqs, n_slots=b, spec_decode=4) == ebase


# --------------------------------------------------------------------------
# the sharded head: logits parity + actual placement
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "two_kernel", "fused"])
def test_apply_head_sharded_logits_close(served, mesh, backend):
    cfg, params, head_params = served
    hidden = jax.random.normal(jax.random.PRNGKey(7), (4, cfg.d_model))
    base = np.asarray(apply_head(head_params, hidden, _HEAD_CFG,
                                 backend=backend))
    sharded = np.asarray(apply_head(head_params, hidden, _HEAD_CFG,
                                    backend=backend, mesh=mesh))
    np.testing.assert_allclose(sharded, base, rtol=1e-5, atol=1e-5)


def test_count_arrays_sharded_over_model(served, mesh):
    """The (L, R, V) count arrays land partitioned on the repetition axis;
    hash params replicate — asserted on the placed LM, not just the rules."""
    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="fused", params=head_params)
    lm = LM(params, cfg, head).with_mesh(mesh)
    spec = lm.head.params["array"].sharding.spec
    assert tuple(spec) == ("model", None, None)
    n_model = 2
    l = lm.head.params["array"].shape[0]
    shard_shapes = {s.data.shape for s in
                    lm.head.params["array"].addressable_shards}
    assert shard_shapes == {(l // n_model, _HEAD_CFG.n_buckets,
                             cfg.vocab_size)}
    for name in ("proj", "w", "b"):
        assert lm.head.params[name].sharding.is_fully_replicated


@pytest.mark.parametrize("quant", ["int8", "int4"])
@pytest.mark.parametrize("backend", ["two_kernel", "fused"])
def test_apply_head_quantized_sharded_logits_close(served, mesh, backend,
                                                   quant):
    """Quantized heads shard too (DESIGN.md §12): int8 rows and int4 packed
    row-pairs partition over ``model`` with their (L, R) scales, and the
    sharded logits match the single-device quantized path.  L=32 with
    model=2 keeps int4 shard boundaries byte-aligned (DESIGN.md §12)."""
    from repro.core.sketch_lm_head import quantize_head

    cfg, params, head_params = served
    qhead = quantize_head(head_params, quant)
    hidden = jax.random.normal(jax.random.PRNGKey(11), (4, cfg.d_model))
    base = np.asarray(apply_head(qhead, hidden, _HEAD_CFG,
                                 backend=backend, quant=quant))
    sharded = np.asarray(apply_head(qhead, hidden, _HEAD_CFG,
                                    backend=backend, quant=quant, mesh=mesh))
    np.testing.assert_allclose(sharded, base, rtol=1e-5, atol=1e-5)
    # And the quantized head agrees with the f32 head up to rounding noise.
    f32 = np.asarray(apply_head(head_params, hidden, _HEAD_CFG,
                                backend=backend, mesh=mesh))
    assert np.abs(sharded - f32).max() < float(qhead["scale"].max())


def test_quantized_head_scales_sharded_over_model(served, mesh):
    """On the placed LM, the int8 store keeps the f32 head's row partition
    and the per-row scales partition with it (rules.py sketch/scale)."""
    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="fused",
                      params=head_params).quantized("int8")
    lm = LM(params, cfg, head).with_mesh(mesh)
    assert lm.head.params["array"].dtype == jnp.int8
    assert tuple(lm.head.params["array"].sharding.spec) == \
        ("model", None, None)
    assert tuple(lm.head.params["scale"].sharding.spec) == ("model", None)
    l = _HEAD_CFG.n_rows
    shard_shapes = {s.data.shape for s in
                    lm.head.params["scale"].addressable_shards}
    assert shard_shapes == {(l // 2, _HEAD_CFG.n_buckets)}


@pytest.mark.parametrize("quant", ["int8", "int4"])
def test_quantized_generate_on_mesh(served, mesh, quant):
    """End-to-end: a quantized head serves on the mesh, deterministic and
    engine-vs-static bitwise (same invariants as the f32 head)."""
    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="fused",
                      params=head_params).quantized(quant)
    lm = LM(params, cfg, head).with_mesh(mesh)
    b, p, g = 4, 6, 5
    prompts = jax.random.randint(jax.random.PRNGKey(13), (b, p), 0,
                                 cfg.vocab_size)
    static = np.asarray(lm.generate(prompts, g))
    again = np.asarray(lm.generate(prompts, g))
    np.testing.assert_array_equal(again, static)
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b)
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      static[i, p:])


def test_model_params_sharded(served, mesh):
    cfg, params, head_params = served
    lm = LM(params, cfg).with_mesh(mesh)
    spec = tuple(lm.params["embed"].sharding.spec)
    assert spec[:1] == ("model",)  # vocab axis over model (rules.py)


# --------------------------------------------------------------------------
# the slot pool stays sharded through insert / decode / reset
# --------------------------------------------------------------------------

def test_engine_pool_shardings_preserved(served, mesh):
    from repro.sharding.rules import cache_shardings

    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="ref", params=head_params)
    lm = LM(params, cfg, head).with_mesh(mesh)
    engine = lm.engine(n_slots=4, max_seq=12)
    expected = cache_shardings(engine.pool, mesh)

    def check(pool):
        ok = jax.tree.map(
            lambda leaf, want: leaf.sharding.is_equivalent_to(want, leaf.ndim),
            pool, expected)
        assert all(jax.tree.leaves(ok))

    check(engine.pool)                       # freshly placed
    rng = np.random.default_rng(1)
    for i in range(5):
        engine.submit(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32),
                      4, arrival=i)
    engine.run()                             # insert + decode + reset cycles
    check(engine.pool)


def test_chunked_engine_on_mesh_matches_k1_and_keeps_shardings(served, mesh):
    """Decode megasteps on the mesh (DESIGN.md §10): chunked engine streams
    equal the per-token-tick streams bitwise ON the mesh, the donated pool
    is never reused after a megastep (donation deletes buffers — any
    use-after-donate raises), and the pool keeps its cache shardings
    through admit → megastep → reset cycles."""
    from repro.sharding.rules import cache_shardings

    cfg, params, head_params = served
    head = SketchHead(cfg=_HEAD_CFG, backend="ref", params=head_params)
    lm = LM(params, cfg, head).with_mesh(mesh)
    b, p, g = 4, 6, 5
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(0, cfg.vocab_size, p, dtype=np.int32), g)
            for _ in range(b)]
    base = lm.serve(reqs, n_slots=b)
    engine = lm.engine(n_slots=b, max_seq=p + g, decode_chunk=4)
    for prompt, gen in reqs:
        engine.submit(prompt, gen)
    got = engine.run()
    assert got == base
    expected = cache_shardings(engine.pool, mesh)
    ok = jax.tree.map(
        lambda leaf, want: leaf.sharding.is_equivalent_to(want, leaf.ndim),
        engine.pool, expected)
    assert all(jax.tree.leaves(ok))


# --------------------------------------------------------------------------
# mesh spec parsing
# --------------------------------------------------------------------------

def test_parse_mesh_specs(mesh):
    assert parse_mesh(None) is None
    assert parse_mesh(mesh) is mesh
    m = parse_mesh("2x2")
    assert dict(zip(m.axis_names, m.devices.shape)) == {"data": 2, "model": 2}
    with pytest.raises(ValueError, match="not of the form"):
        parse_mesh("banana")
    with pytest.raises(ValueError, match="devices"):
        parse_mesh("64x64")
