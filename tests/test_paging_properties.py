"""Property-based invariants for the paged-pool host bookkeeping.

``PagePool``/``PrefixCache`` (launch/paging.py) are pure numpy/stdlib, so
random operation sequences — admit-with-miss (alloc + register), admit-
with-hit (shared mapping), COW remap, retire, LRU eviction — drive them at
hypothesis speed with no device state.  Invariants after every step:

* refcounts equal live references (page-table entries + prefix-cache entry
  references), checked exhaustively by ``check_invariants``;
* a page returns to the free list exactly when its refcount hits 0, and is
  handed out again only from there (no use-after-free, no double-free —
  ``decref`` of a free page asserts);
* the zero page is never allocated, never freed, never remapped;
* allocation order is deterministic: replaying the same op sequence yields
  the same page ids;
* writes through one slot's table (simulated on a numpy arena the way the
  device commit indexes pages) leave every page referenced by *other* slots
  or prefix entries bitwise frozen — the COW discipline's contract.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests are skipped without hypothesis")
from hypothesis import given, settings, strategies as st

from repro.launch.paging import ZERO_PAGE, PagePool, PrefixCache

_SMALL = settings(max_examples=60, deadline=None)


class _Harness:
    """Random-op driver with a shadow model + numpy arena.

    The arena stands in for the device page buffers: page 0 stays zero,
    every write goes through a slot's table exactly like the device commit
    (``pages[table[slot, j]]``), and COW runs the engine's discipline —
    copy the page when its refcount exceeds 1, then write the copy.
    """

    def __init__(self, num_pages, n_slots, pages_per_slot, page_size=4):
        self.pool = PagePool(num_pages, n_slots, pages_per_slot)
        self.prefix = PrefixCache(self.pool)
        self.arena = np.zeros((num_pages, page_size), np.int64)
        self.live = {}          # slot -> key it serves (miss slots: None)
        self.n_slots = n_slots
        self.npp = pages_per_slot
        self.alloc_log = []
        self.stamp = 0

    def _alloc(self, n):
        ids = self.pool.alloc(n)
        while ids is None and self.prefix.evict_lru():
            ids = self.pool.alloc(n)
        if ids is not None:
            self.alloc_log.extend(ids)
        return ids

    def admit_miss(self, slot, n_pages, key, register):
        ids = self._alloc(n_pages)
        if ids is None:
            return
        self.pool.map_slot(slot, ids, owned=True)
        self.stamp += 1
        for pid in ids:
            self.arena[pid] = self.stamp      # "prefill" content
        if register and key not in self.prefix:
            self.prefix.register(key, ids, None, np.zeros(3), n_pages)
        self.live[slot] = key

    def admit_hit(self, slot, key):
        entry = self.prefix.get(key)
        if entry is None:
            return
        self.pool.map_slot(slot, entry.page_ids, owned=False)
        self.live[slot] = key

    def write(self, slot, j):
        """Decode write through the table at index ``j``, COW first."""
        pid = int(self.pool.table[slot, j])
        if pid == ZERO_PAGE:
            ids = self._alloc(1)
            if ids is None:
                return
            self.pool.map_index(slot, j, ids[0])
            pid = ids[0]
        elif self.pool.refcount[pid] > 1:
            ids = self._alloc(1)
            if ids is None:
                return
            self.arena[ids[0]] = self.arena[pid]
            self.pool.remap(slot, j, ids[0])
            pid = ids[0]
        self.stamp += 1
        self.arena[pid, self.stamp % self.arena.shape[1]] = self.stamp

    def retire(self, slot):
        self.pool.clear_slot(slot)
        self.live.pop(slot, None)

    def check(self):
        self.pool.check_invariants(self.prefix.external_refs())
        assert (self.arena[ZERO_PAGE] == 0).all(), "zero page written"
        # Every refcount-0 page is on the free list and vice versa is part
        # of check_invariants; here: no table row maps a freed page.
        for pid in self.pool.table.ravel():
            if pid != ZERO_PAGE:
                assert self.pool.refcount[pid] > 0


def _run_ops(ops, num_pages, n_slots, npp):
    h = _Harness(num_pages, n_slots, npp)
    for kind, a, b, c in ops:
        slot = a % n_slots
        if kind == 0:
            if slot not in h.live and not h.pool.table[slot].any():
                h.admit_miss(slot, 1 + b % npp, bytes([c % 5]), c % 2 == 0)
        elif kind == 1:
            if slot not in h.live and not h.pool.table[slot].any():
                h.admit_hit(slot, bytes([c % 5]))
        elif kind == 2:
            if slot in h.live:
                h.write(slot, b % npp)
        elif kind == 3:
            if slot in h.live:
                h.retire(slot)
        elif kind == 4:
            h.prefix.evict_lru()
        h.check()
    return h


_OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 7), st.integers(0, 7),
              st.integers(0, 9)),
    min_size=1, max_size=60)


@_SMALL
@given(ops=_OPS)
def test_refcounts_track_live_references(ops):
    """After every op: refcount == table refs + entry refs, free list holds
    exactly the refcount-0 pages, zero page untouched (checked in-loop)."""
    h = _run_ops(ops, num_pages=24, n_slots=4, npp=4)
    # Drain everything: all pages must come home.
    for slot in list(h.live):
        h.retire(slot)
    while h.prefix.evict_lru():
        pass
    h.check()
    assert h.pool.pages_in_use == 0
    assert h.pool.n_free == h.pool.num_pages - 1


@_SMALL
@given(ops=_OPS)
def test_allocation_is_deterministic(ops):
    """The same op sequence replays to the same page ids — serving runs
    are bitwise reproducible at the allocator level."""
    a = _run_ops(ops, num_pages=24, n_slots=4, npp=4)
    b = _run_ops(ops, num_pages=24, n_slots=4, npp=4)
    assert a.alloc_log == b.alloc_log
    assert (a.pool.table == b.pool.table).all()
    assert (a.arena == b.arena).all()


@_SMALL
@given(ops=_OPS, victim=st.integers(0, 3))
def test_slot_ops_freeze_other_slots_pages(ops, victim):
    """Writing through / retiring one slot never mutates a page that other
    slots or prefix entries still reference (the COW contract)."""
    h = _run_ops(ops, num_pages=32, n_slots=4, npp=4)
    others = {}
    for slot in range(h.n_slots):
        if slot == victim:
            continue
        for pid in h.pool.slot_pages(slot):
            others[pid] = h.arena[pid].copy()
    for entry in h.prefix._entries.values():
        for pid in entry.page_ids:
            others[pid] = h.arena[pid].copy()
    if victim in h.live:
        for j in range(h.npp):
            h.write(victim, j)
        h.check()
        h.retire(victim)
        h.check()
    # Pages the victim shared were COW'd before its writes landed; pages it
    # owned outright are not in `others`.  Shared + entry pages: frozen.
    for pid, before in others.items():
        assert (h.arena[pid] == before).all(), f"page {pid} mutated"


def test_double_free_asserts():
    pool = PagePool(8, 2, 2)
    (pid,) = pool.alloc(1)
    pool.decref(pid)
    with pytest.raises(AssertionError, match="double free"):
        pool.decref(pid)


def test_freed_page_reused_only_after_zero_refcount():
    pool = PagePool(4, 2, 2)          # 3 usable pages
    ids = pool.alloc(3)
    assert pool.alloc(1) is None      # pool dry while all referenced
    pool.incref(ids[0])
    pool.decref(ids[0])
    assert pool.alloc(1) is None      # still referenced once
    pool.decref(ids[0])
    assert pool.alloc(1) == [ids[0]]  # back only after refcount hit 0


def test_zero_page_is_pinned():
    pool = PagePool(4, 1, 2)
    with pytest.raises(AssertionError):
        pool.decref(ZERO_PAGE)
    with pytest.raises(AssertionError):
        pool.incref(ZERO_PAGE)
    assert ZERO_PAGE not in pool._free
