"""End-to-end system behaviour: training improves loss, checkpoint resume
is bit-consistent, serving generates, sketched LM head approximates the
dense head."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sketch_lm_head import (apply_head, distill_head, freeze_head,
                                       head_costs)
from repro.core.distill import DistillConfig
from repro.data.pipeline import DataConfig, PrefetchingLoader, synthetic_batch
from repro.launch.steps import train_step
from repro.models.config import SketchHeadConfig
from repro.models.model import init_model
from repro.optim.adamw import OptimizerConfig, init_adamw


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("granite-8b", smoke=True)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=8)
    step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg))
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(data_cfg, s).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    return cfg, params, losses


def test_training_reduces_loss(trained):
    _, _, losses = trained
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.1, (first, last)


def test_loss_starts_near_uniform(trained):
    cfg, _, losses = trained
    assert abs(losses[0] - np.log(cfg.vocab_size)) < 1.5


def test_train_resume_matches_continuous(tmp_path):
    """Stop at step 5, checkpoint, restore — trajectories must agree."""
    from repro.checkpoint.manager import CheckpointManager

    cfg = get_config("musicgen-large", smoke=True)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg))

    def run(n, params, opt, start=0):
        for s in range(start, n):
            batch = {k: jnp.asarray(v)
                     for k, v in synthetic_batch(data_cfg, s).items()}
            params, opt, m = step(params, opt, batch)
        return params, opt, m

    p0 = init_model(jax.random.PRNGKey(0), cfg)
    o0 = init_adamw(p0)
    p_cont, o_cont, m_cont = run(10, p0, o0)

    p1 = init_model(jax.random.PRNGKey(0), cfg)
    o1 = init_adamw(p1)
    p_half, o_half, _ = run(5, p1, o1)
    cm = CheckpointManager(tmp_path)
    cm.save(5, jax.tree.map(np.asarray, (p_half, o_half)), blocking=True)
    (p_rest, o_rest), _ = cm.restore((p_half, o_half))
    p_resumed, o_resumed, m_res = run(10, p_rest, o_rest, start=5)

    np.testing.assert_allclose(float(m_res["loss"]), float(m_cont["loss"]),
                               rtol=1e-4)


def test_serve_generates(trained):
    from repro.launch.serve import generate
    cfg, params, _ = trained
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, 6), 0,
                                 cfg.vocab_size)
    out = generate(params, cfg, prompts, gen_len=5)
    assert out.shape == (2, 11)
    assert int(out.max()) < cfg.vocab_size


def test_sketch_lm_head_approximates_dense(trained):
    cfg, params, _ = trained
    head_cfg = SketchHeadConfig(n_rows=512, n_buckets=16, k=1, proj_dim=32,
                                bandwidth=2.0)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    hiddens = jax.random.normal(jax.random.PRNGKey(3), (2048, cfg.d_model))
    kparams, metrics = distill_head(
        jax.random.PRNGKey(4), table, hiddens, head_cfg, n_points=512,
        distill_cfg=DistillConfig(n_steps=2000, lr=5e-3))
    head = freeze_head(jax.random.PRNGKey(5), kparams, head_cfg)
    test_h = jax.random.normal(jax.random.PRNGKey(6), (128, cfg.d_model))
    dense = np.asarray(test_h @ np.asarray(table, np.float32).T)
    sk = np.asarray(apply_head(head, test_h, head_cfg,
                               backend="two_kernel"))
    # Rank agreement + logit correlation (thresholds from the measured
    # sweep in EXPERIMENTS.md §Paper: hits≈0.66, corr≈0.77 at this budget).
    top5 = np.argsort(-dense, axis=1)[:, :5]
    hits = np.mean([int(np.argmax(sk[i])) in top5[i] for i in range(128)])
    corr = np.corrcoef(dense.ravel(), sk.ravel())[0, 1]
    assert hits > 0.45, hits
    assert corr > 0.6, corr
    # The fused serving kernel must reproduce the two-kernel logits on the
    # distilled head (same hash indices bit-for-bit).
    sk_fused = np.asarray(apply_head(head, test_h, head_cfg,
                                     backend="fused"))
    np.testing.assert_allclose(sk_fused, sk, rtol=1e-5, atol=1e-5)
    costs = head_costs(head_cfg, cfg.d_model, cfg.vocab_size)
    assert costs["flop_ratio"] > 0   # accounting sanity


def test_prefetching_loader():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=4)
    loader = PrefetchingLoader(cfg)
    s0, b0 = next(loader)
    s1, b1 = next(loader)
    loader.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(b0["tokens"],
                                  synthetic_batch(cfg, 0)["tokens"])
