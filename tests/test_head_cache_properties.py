"""LRU ``HeadCache`` invariants under random acquire/release/publish
traffic (hypothesis; DESIGN.md §14).

The cache is pure host bookkeeping over a device-side bank, so the suite
drives it against an independent shadow model (a dict + explicit LRU
list) and checks after every operation:

* capacity is never exceeded, and a resident tenant's bank row always
  holds *its own* params (slots never alias across tenants);
* the loader runs exactly once per miss — hits never reload;
* a pinned tenant (refcount > 0) is never evicted, and evicting when
  every resident tenant is pinned raises instead of corrupting state;
* evictions pick the least-recently-*used* unpinned tenant (acquire and
  publish both refresh recency);
* replaying the same operation sequence reproduces the same stats — the
  cache is deterministic host state.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import HeadCache  # noqa: E402

_N_TENANTS = 6


def _loader_for(counter):
    def load(tenant):
        counter[tenant] = counter.get(tenant, 0) + 1
        t = int(tenant.split("-")[1])
        return {"array": np.full((2, 3), t, np.float32),
                "w": np.full((4,), 10 * t, np.float32)}
    return load


#: One op: (kind, tenant index).  Releases/publishes on non-acquired
#: tenants are skipped by the driver (the cache raises on them — that
#: contract has its own test below).
_ops = st.lists(
    st.tuples(st.sampled_from(["acquire", "release", "publish"]),
              st.integers(0, _N_TENANTS - 1)),
    max_size=60)


class _Shadow:
    """Independent reference model: resident set + LRU list + refcounts."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.resident = []          # LRU → MRU
        self.refs = {}

    def acquire(self, t):
        if t in self.resident:
            self.resident.remove(t)
            self.resident.append(t)
            self.refs[t] += 1
            return "hit"
        if len(self.resident) == self.capacity:
            victims = [x for x in self.resident if self.refs[x] == 0]
            if not victims:
                return "full"
            evicted = victims[0]    # least recently used unpinned
            self.resident.remove(evicted)
            del self.refs[evicted]
        self.resident.append(t)
        self.refs[t] = 1
        return "miss"

    def release(self, t):
        self.refs[t] -= 1

    def touch(self, t):
        self.resident.remove(t)
        self.resident.append(t)


@given(ops=_ops, capacity=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_lru_cache_matches_shadow_model(ops, capacity):
    counter = {}
    cache = HeadCache(_loader_for(counter), capacity=capacity)
    shadow = _Shadow(capacity)
    pinned_live = {}                      # tenant -> outstanding acquires
    for kind, i in ops:
        t = f"tenant-{i}"
        if kind == "acquire":
            expect = shadow.acquire(t)
            if expect == "full":
                with pytest.raises(RuntimeError, match="pinned"):
                    cache.acquire(t)
                shadow_stats_only = True  # noqa: F841 — no state change
                continue
            before = counter.get(t, 0)
            cache.acquire(t)
            pinned_live[t] = pinned_live.get(t, 0) + 1
            loads = counter.get(t, 0) - before
            assert loads == (1 if expect == "miss" else 0), (
                f"{expect} ran the loader {loads} times")
        elif kind == "release":
            if pinned_live.get(t, 0) == 0:
                with pytest.raises(ValueError):
                    cache.release(t)
                continue
            cache.release(t)
            shadow.release(t)
            pinned_live[t] -= 1
        else:  # publish
            if t not in shadow.resident:
                with pytest.raises(KeyError):
                    cache.publish(t, _loader_for({})(t))
                continue
            cache.publish(t, _loader_for({})(t))
            shadow.touch(t)

        # -- invariants after every op --------------------------------
        assert set(cache.resident()) == set(shadow.resident)
        assert len(cache.resident()) <= capacity
        for r in shadow.resident:           # rows never alias
            idx = int(r.split("-")[1])
            got = np.asarray(cache.tenant_params(r)["array"])
            np.testing.assert_array_equal(got, np.full((2, 3), idx))
        for p, n in pinned_live.items():    # pinned ⇒ resident
            if n > 0:
                assert p in cache.resident()
    # Loader ran exactly once per recorded miss.
    assert sum(counter.values()) == cache.stats["loads"] \
        == cache.stats["misses"]


@given(ops=_ops, capacity=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_replay_is_deterministic(ops, capacity):
    def run():
        cache = HeadCache(_loader_for({}), capacity=capacity)
        live = {}
        for kind, i in ops:
            t = f"tenant-{i}"
            try:
                if kind == "acquire":
                    cache.acquire(t)
                    live[t] = live.get(t, 0) + 1
                elif kind == "release":
                    cache.release(t)
                    live[t] -= 1
                else:
                    cache.publish(t, _loader_for({})(t))
            except (RuntimeError, ValueError, KeyError):
                pass
        return dict(cache.stats), list(cache.resident())

    assert run() == run()


def test_release_without_acquire_raises():
    cache = HeadCache(_loader_for({}), capacity=2)
    cache.acquire("tenant-0")
    cache.release("tenant-0")
    with pytest.raises(ValueError):
        cache.release("tenant-0")


def test_capacity_below_one_rejected():
    with pytest.raises(ValueError):
        HeadCache(_loader_for({}), capacity=0)


def test_all_pinned_eviction_raises_and_preserves_state():
    cache = HeadCache(_loader_for({}), capacity=2)
    cache.acquire("tenant-0")
    cache.acquire("tenant-1")
    with pytest.raises(RuntimeError, match="pinned"):
        cache.acquire("tenant-2")
    assert set(cache.resident()) == {"tenant-0", "tenant-1"}
    cache.release("tenant-0")
    cache.acquire("tenant-2")          # tenant-0 now evictable
    assert set(cache.resident()) == {"tenant-1", "tenant-2"}
    assert cache.stats["evictions"] == 1
