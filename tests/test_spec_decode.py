"""Speculative self-decode parity: sketch drafts, dense verifies, bitwise.

The speculative megastep (launch/decode_loop.py, DESIGN.md §11) drafts K
tokens through the cheap sketch head and verifies the block with one
batched dense pass.  Its whole contract is that speculation is *invisible*
in the tokens: every emitted token is the dense head's draw under the same
split-key chain the plain decode loop walks, so greedy and seeded streams
must be bitwise-equal to pure dense decode across K ∈ {1, 4, 16}, through
both the static ``generate`` path and the continuous-batching engine, for
every draft-head backend (fused / two_kernel / ref), including EOS firing
mid-block and — since the random test head is rejected almost every block —
rejection mid-block as the steady state.  Draft quality may only ever
change *throughput* (how many drafts commit per verify), never a single
token.  Donation is in the loop throughout: the spec megastep donates its
cache like the plain megastep, so any use-after-donate raises on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LM, Sampler, SketchHead, SketchHeadConfig
from repro.configs import get_config
from repro.core.sketch_lm_head import HEAD_BACKENDS, freeze_head

_KS = [1, 4, 16]
_HEAD_CFG = SketchHeadConfig(n_rows=32, n_buckets=8, k=1, proj_dim=16,
                             bandwidth=2.0)
_SAMPLERS = {
    "greedy": Sampler(),
    "seeded": Sampler(temperature=0.9, top_k=12, seed=7),
}


@pytest.fixture(scope="module")
def served():
    from repro.models.model import init_model

    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    kp, ka, kj, kf = jax.random.split(jax.random.PRNGKey(42), 4)
    kparams = {
        "points": jax.random.normal(kp, (128, _HEAD_CFG.proj_dim)),
        "alphas": jax.random.normal(ka, (128, cfg.vocab_size)) * 0.01,
        "proj": jax.random.normal(kj, (cfg.d_model, _HEAD_CFG.proj_dim))
        / np.sqrt(cfg.d_model),
    }
    frozen = freeze_head(kf, kparams, _HEAD_CFG)
    heads = {be: SketchHead(cfg=_HEAD_CFG, backend=be, params=frozen)
             for be in HEAD_BACKENDS}
    return cfg, params, heads


def _lms(served, backend):
    """(drafting LM, pure-dense baseline LM) sharing params."""
    cfg, params, heads = served
    return LM(params, cfg, heads[backend]), LM(params, cfg)


def _prompts(cfg, b=3, p=5):
    return jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                              cfg.vocab_size)


# --------------------------------------------------------------------------
# the parity grid: K × backend × sampler × {generate, engine}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", sorted(_SAMPLERS))
@pytest.mark.parametrize("backend", HEAD_BACKENDS)
def test_generate_bitwise_equal_to_dense(served, backend, sampler):
    """Static generate: spec-decode streams == pure dense streams, bitwise,
    at every K — the random head rejects nearly every draft, so this grid
    is the rejection-mid-block path almost every megastep."""
    lm, dense = _lms(served, backend)
    prompts = _prompts(lm.cfg)
    base = np.asarray(dense.generate(prompts, 9, sampler=_SAMPLERS[sampler]))
    for k in _KS:
        got = np.asarray(lm.generate(prompts, 9, sampler=_SAMPLERS[sampler],
                                     spec_decode=k))
        np.testing.assert_array_equal(
            got, base, err_msg=f"spec_decode={k} diverged from dense "
            f"({backend}, {sampler})")


@pytest.mark.parametrize("sampler", sorted(_SAMPLERS))
@pytest.mark.parametrize("backend", HEAD_BACKENDS)
def test_engine_bitwise_equal_to_dense(served, backend, sampler):
    """Engine: speculative ticks emit exactly the dense per-token-tick
    streams (synchronized arrivals keep the admission order — and so the
    seeded key chain — identical across K)."""
    lm, dense = _lms(served, backend)
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    reqs = [(np.asarray(prompts[i]), g) for i in range(b)]
    base = dense.serve(reqs, n_slots=b, sampler=_SAMPLERS[sampler])
    for k in _KS:
        got = lm.serve(reqs, n_slots=b, sampler=_SAMPLERS[sampler],
                       spec_decode=k)
        assert got == base, (f"engine spec_decode={k} diverged "
                             f"({backend}, {sampler})")


def test_engine_spec_matches_static_generate(served):
    """Cross-path: the speculative engine reproduces the dense host-loop
    static generate (scheduler, spec megastep, rollback, and slot ops all
    in the loop)."""
    lm, dense = _lms(served, "fused")
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    expected = np.asarray(dense.generate(prompts, g))
    finished = lm.serve([(np.asarray(prompts[i]), g) for i in range(b)],
                        n_slots=b, spec_decode=4)
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(finished[i]),
                                      expected[i, p:])


def test_engine_spec_staggered_matches_solo_generate(served):
    """Slot recycling under speculative ticks: every request of a
    staggered, mixed-length stream still emits exactly its solo dense
    stream (the draft clamp tracks arrivals and per-slot budgets)."""
    lm, dense = _lms(served, "ref")
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, lm.cfg.vocab_size, 4 + (i % 3), dtype=np.int32),
             3 + 2 * (i % 3), i) for i in range(5)]
    finished = lm.serve(reqs, n_slots=2, spec_decode=4)
    for rid, (prompt, gen, _) in enumerate(reqs):
        solo = np.asarray(dense.generate(prompt[None], gen))
        np.testing.assert_array_equal(np.asarray(finished[rid]),
                                      solo[0, len(prompt):])


# --------------------------------------------------------------------------
# EOS + rejection mid-block
# --------------------------------------------------------------------------

def test_eos_mid_block_generate(served):
    """An EOS inside a draft block retires the row in-megastep: the stream
    matches the dense host loop's (pad tail included) at every K."""
    lm, dense = _lms(served, "fused")
    prompts = _prompts(lm.cfg)
    plain = np.asarray(dense.generate(prompts, 9))
    eos = int(plain[0, 5 + 3])           # emitted mid-way through block 1
    base = np.asarray(dense.generate(prompts, 9, eos_id=eos, pad_id=0))
    assert (base[0] == 0).any()          # the EOS actually fired
    for k in (4, 16):
        got = np.asarray(lm.generate(prompts, 9, eos_id=eos, pad_id=0,
                                     spec_decode=k))
        np.testing.assert_array_equal(got, base)


def test_eos_mid_block_engine(served):
    """Engine: a verify-pass EOS mid-block retires the request with exactly
    the dense stream (uncommitted block entries are discarded, the slot
    resets and is reusable)."""
    lm, dense = _lms(served, "fused")
    b, p, g = 3, 5, 9
    prompts = _prompts(lm.cfg, b, p)
    plain = np.asarray(dense.generate(prompts, g))
    eos = int(plain[0, p + 3])
    reqs = [(np.asarray(prompts[i]), g) for i in range(b)]
    base = dense.serve(reqs, n_slots=b, eos_id=eos)
    assert any(s[-1] == eos and len(s) < g for s in base.values())
    for k in (4, 16):
        engine = lm.engine(n_slots=b, max_seq=p + g, eos_id=eos,
                           spec_decode=k)
        rids = [engine.submit(pr, mx) for pr, mx in reqs]
        got = engine.run()
        assert {r: got[r] for r in rids} == base
        assert engine.stats["admitted"] == engine.stats["retired"] == b
        assert engine.sched.n_free == b   # every slot recycled


def test_rejection_mid_block_accounting(served):
    """The random head's drafts are mostly rejected: the stats must show
    real rejections (accepted < drafted), at least one commit per verify
    (the verify pass itself always yields the next dense token), and the
    stream is unchanged — rejection costs throughput, never tokens."""
    lm, dense = _lms(served, "fused")
    prompts = _prompts(lm.cfg)
    base = np.asarray(dense.generate(prompts, 9))
    got, stats = lm.generate(prompts, 9, spec_decode=4, return_stats=True)
    np.testing.assert_array_equal(np.asarray(got), base)
    assert stats["verify_calls"] >= 2          # rejections forced re-drafts
    assert stats["accepted_draft_tokens"] < stats["draft_tokens"]
    # every megastep commits >= 1 token: 8 post-prefill tokens emitted in
    # verify_calls dispatches of <= 4 drafts each
    assert stats["verify_calls"] <= 8


# --------------------------------------------------------------------------
# the serve-fns knob, validation, donation
# --------------------------------------------------------------------------

def test_jitted_serve_fns_spec_decode_knob(served):
    """The spec_decode knob on jitted_serve_fns: the returned struct still
    unpacks as the legacy 4-tuple, shares the (cfg, head, mesh) compile
    cache (a spec sampler must not recompile the model steps), and carries
    the memoized speculative megastep."""
    from repro.launch.decode_loop import jitted_spec_megastep
    from repro.launch.steps import jitted_serve_fns

    cfg, _, heads = served
    spec = heads["fused"].without_params()
    base = jitted_serve_fns(cfg, heads["fused"])
    a = jitted_serve_fns(cfg, heads["fused"], sampler=Sampler(),
                         spec_decode=4, eos_id=3)
    prefill, decode, insert, reset = a            # legacy unpacking
    assert decode is base.decode                  # shared compile cache
    assert a.megastep is None
    assert a.spec_megastep is jitted_spec_megastep(
        cfg, spec, Sampler(), 4, eos_id=3, masked=True)
    with pytest.raises(ValueError, match="sampler"):
        jitted_serve_fns(cfg, heads["fused"], spec_decode=4)
    with pytest.raises(ValueError, match="mutually exclusive"):
        jitted_serve_fns(cfg, heads["fused"], sampler=Sampler(),
                         spec_decode=4, decode_chunk=4)
    with pytest.raises(ValueError, match="spec_decode"):
        jitted_serve_fns(cfg, heads["fused"], sampler=Sampler(),
                         spec_decode=-1)


def test_spec_decode_validation_surfaces(served):
    """generate / engine / serve all reject spec_decode × decode_chunk and
    negative K — the contract is uniform across the stack."""
    cfg, params, heads = served
    lm = LM(params, cfg, heads["fused"])
    prompts = _prompts(cfg, 1, 4)
    with pytest.raises(ValueError, match="mutually exclusive"):
        lm.generate(prompts, 4, spec_decode=4, decode_chunk=4)
    with pytest.raises(ValueError, match="spec_decode"):
        lm.generate(prompts, 4, spec_decode=-2)
    with pytest.raises(ValueError, match="mutually exclusive"):
        lm.engine(n_slots=2, max_seq=8, spec_decode=4, decode_chunk=4)
    with pytest.raises(ValueError, match="spec_decode"):
        lm.engine(n_slots=2, max_seq=8, spec_decode=-1)


def test_spec_megastep_donates_cache(served):
    """The speculative megastep donates its cache argument like the plain
    megastep: the passed-in buffers are deleted on CPU, so draft K steps +
    verify + rollback cost zero extra cache copies."""
    from repro.launch.decode_loop import jitted_spec_megastep
    from repro.launch.steps import jitted_serve_fns
    from repro.models.model import init_decode_cache

    cfg, params, heads = served
    head = heads["fused"]
    prefill, decode, insert, reset = jitted_serve_fns(cfg, head)
    logits, cache = prefill(params, _prompts(cfg, 2, 4),
                            cache=init_decode_cache(cfg, 2, 8))
    fn = jitted_spec_megastep(cfg, head.without_params(), Sampler(), 4,
                              masked=True)
    old = cache
    out = fn(params, cache, jnp.zeros(2, jnp.int32),
             jnp.full(2, 4, jnp.int32), Sampler().init_key(),
             head_params=head.params, active=jnp.asarray([True, True]))
    jax.block_until_ready(out[0])
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(old))
