"""Dry-run smoke: lower+compile smoke-scale cells on the production meshes.

Runs in subprocesses because the 512-placeholder-device XLA flag must be set
before jax initializes (the main pytest process keeps 1 device)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(arch, shape, mesh):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--smoke"]
    return subprocess.run(
        cmd, cwd=ROOT, capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", [
    ("granite-8b", "train_4k", "single"),
    ("granite-8b", "decode_32k", "multi"),
    ("mixtral-8x7b", "train_4k", "multi"),
    ("rwkv6-1.6b", "long_500k", "single"),
])
def test_dryrun_smoke_cell(arch, shape, mesh):
    res = _run(arch, shape, mesh)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(
        (ROOT / "results" / "dryrun" / f"{arch}__{shape}__{mesh}.json"
         ).read_text())
    assert out["flops"] > 0
    assert out["n_devices"] == (512 if mesh == "multi" else 256)
