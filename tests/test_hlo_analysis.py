"""HLO analyzer: trip-weighted FLOP/byte/collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    r = analyze(_hlo(lambda a, b: a @ b, x, w))
    assert r["flops"] == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplies():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a, b):
        return jax.lax.scan(lambda c, _: (c @ b, None), a, None, length=7)[0]

    r = analyze(_hlo(f, x, w))
    assert r["flops"] == 7 * 2 * 32**3


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(a, b):
        def outer(c, _):
            inner = jax.lax.scan(lambda ci, _: (ci @ b, None), c, None,
                                 length=5)[0]
            return inner, None
        return jax.lax.scan(outer, a, None, length=3)[0]

    r = analyze(_hlo(f, x, w))
    assert r["flops"] == 15 * 2 * 16**3


def test_batched_dot_counts_batch_dims():
    x = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    r = analyze(_hlo(lambda a, b: jnp.einsum("bsk,kd->bsd", a, b), x, w))
    assert r["flops"] == 2 * 4 * 8 * 8 * 16


def test_bytes_positive_and_bounded():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = analyze(_hlo(lambda a: a * 2.0 + 1.0, x))
    nbytes = 256 * 256 * 4
    assert nbytes <= r["bytes_accessed"] <= 6 * nbytes


def test_elementwise_flops_counted():
    x = jax.ShapeDtypeStruct((128,), jnp.float32)
    r = analyze(_hlo(lambda a: jnp.tanh(a) * a, x))
    assert r["elementwise_flops"] >= 2 * 128
