import jax
import pytest

# Tests run on the single host CPU device; the 512-device dry-run has its
# own subprocess tests (test_dryrun.py) so device count stays 1 here.
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_compile_cache():
    """Free compiled executables at module boundaries.

    The suite compiles hundreds of distinct serving executables in one
    process; letting them all accumulate can segfault the CPU backend's
    JIT inside a late `backend_compile`.  Memoized callables
    (`jitted_serve_fns` etc.) stay valid — they just recompile on next
    use."""
    yield
    jax.clear_caches()
