import jax
import pytest

# Tests run on the single host CPU device; the 512-device dry-run has its
# own subprocess tests (test_dryrun.py) so device count stays 1 here.
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
