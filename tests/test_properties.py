"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests are skipped without hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.lsh import LSHConfig, L2LSH, SRPLSH, _fold_subhashes
from repro.core.sketch import mom_estimate
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.runtime.elastic import initial_plan, shrink_plan
from repro.runtime.failure import Action, decide_recovery

_SMALL = settings(max_examples=25, deadline=None)


@_SMALL
@given(st.integers(1, 64), st.integers(1, 5), st.integers(2, 257),
       st.integers(0, 2**31 - 1))
def test_fold_subhashes_in_range(l, k, r, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (7, l, k),
                               -(2**20), 2**20)
    idx = _fold_subhashes(codes, r)
    assert idx.shape == (7, l)
    assert bool(jnp.all((idx >= 0) & (idx < r)))


@_SMALL
@given(st.floats(0.01, 50.0), st.floats(0.01, 50.0), st.integers(1, 4))
def test_l2_collision_prob_monotone(d1, d2, k):
    lsh = L2LSH(LSHConfig(n_rows=1, n_buckets=2, k=k, dim=4, bandwidth=2.0))
    lo, hi = sorted([d1, d2])
    p_lo = float(lsh.collision_probability(jnp.asarray(lo)))
    p_hi = float(lsh.collision_probability(jnp.asarray(hi)))
    assert 0.0 <= p_hi <= p_lo <= 1.0


@_SMALL
@given(st.integers(0, 2**31 - 1))
def test_srp_collision_prob_bounds(seed):
    lsh = SRPLSH(LSHConfig(n_rows=4, n_buckets=16, k=3, dim=8))
    cos = jax.random.uniform(jax.random.PRNGKey(seed), (5,), minval=-1.0,
                             maxval=1.0)
    p = lsh.collision_probability(cos)
    assert bool(jnp.all((p >= 0) & (p <= 1)))


@_SMALL
@given(st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_mom_between_min_max(g, seed):
    reads = jax.random.normal(jax.random.PRNGKey(seed), (3, g * 4))
    est = mom_estimate(reads, g)
    assert bool(jnp.all(est >= reads.min(-1) - 1e-6))
    assert bool(jnp.all(est <= reads.max(-1) + 1e-6))


@_SMALL
@given(st.integers(0, 1000), st.integers(1, 4), st.integers(0, 3))
def test_synthetic_batch_deterministic_and_sharded(step, n_hosts, host):
    host = host % n_hosts
    base = DataConfig(vocab_size=101, seq_len=17, global_batch=8 * n_hosts,
                      n_hosts=n_hosts, host_id=host)
    b1 = synthetic_batch(base, step)
    b2 = synthetic_batch(base, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 17)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 101
    # host slices of one global batch are disjoint deterministic functions:
    full = DataConfig(vocab_size=101, seq_len=17, global_batch=8 * n_hosts)
    g = synthetic_batch(full, step)
    np.testing.assert_array_equal(g["tokens"][host * 8:(host + 1) * 8],
                                  b1["tokens"])


@_SMALL
@given(st.integers(2, 64), st.integers(1, 8),
       st.lists(st.integers(0, 63), max_size=8))
def test_recovery_plan_invariants(n_replicas, hosts_per_replica, dead):
    n_hosts = n_replicas * hosts_per_replica
    dead = [d % n_hosts for d in dead]
    plan = decide_recovery(n_hosts, dead,
                           hosts_per_replica=hosts_per_replica,
                           n_replicas=n_replicas)
    assert not set(plan.healthy_hosts) & set(dead)
    if plan.action is Action.SHRINK:
        assert 0 < plan.new_data_parallel < n_replicas or not dead
    if not dead:
        assert plan.action is Action.CONTINUE


@_SMALL
@given(st.integers(1, 6), st.integers(2, 16))
def test_shrink_rebalances_batch(hosts_per_replica, n_replicas):
    n_hosts = hosts_per_replica * n_replicas
    gb = n_replicas * 4
    plan = initial_plan(n_hosts, hosts_per_replica, gb)
    new = shrink_plan(plan, [0], gb)   # kill replica 0
    assert new.data == n_replicas - 1
    # Batch invariant: the new global batch divides evenly over survivors.
    assert new.global_batch % new.data == 0
    assert new.grad_accum >= 1
    # When divisibility allows, the global batch is preserved exactly.
    if gb % new.data == 0:
        assert new.global_batch == gb
