"""Serving with the Representer-Sketch LM head through the ``repro.api``
facade (the paper's technique as a first-class serving feature — DESIGN.md
§4/§8): the full distill → freeze → serve flow.

1. distill the dense logit head of a small LM into a kernel model,
2. freeze it into a ``SketchHead`` (per-class RACE arrays + decode backend)
   and save the deployable .npz — kind and backend round-trip with it,
3. serve: ``LM.generate`` decoding through the fused Pallas sketch head
   (hash + gather + mean instead of the d_model×V matmul), and report
   agreement + the analytic cost deltas,
4. engine: ``LM.serve`` runs a staggered request stream through the
   continuous-batching engine with the reloaded head.

  PYTHONPATH=src python examples/serve_sketch_head.py
"""

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import LM, SketchHead, SketchHeadConfig, load_head
from repro.configs import get_config
from repro.core.distill import DistillConfig
from repro.core.sketch_lm_head import distill_head, freeze_head, head_costs
from repro.models.model import init_model

HEAD_PATH = Path(__file__).resolve().parents[1] / "results" / "sketch_head" \
    / "musicgen-large-smoke.npz"


def main():
    cfg = get_config("musicgen-large", smoke=True)
    cfg = dataclasses.replace(cfg, vocab_size=512)
    params = init_model(jax.random.PRNGKey(0), cfg)
    head_cfg = SketchHeadConfig(n_rows=512, n_buckets=16, k=1, proj_dim=32,
                                bandwidth=2.0)

    # Representative final hiddens for distillation (production would sample
    # real decode-time hiddens; statistics are what matters here).
    hiddens = jax.random.normal(jax.random.PRNGKey(2), (1024, cfg.d_model))

    table = params["embed"] if cfg.tie_embeddings else params["head"]
    print("1. distilling dense head → kernel representation …")
    kparams, metrics = distill_head(
        jax.random.PRNGKey(3), table, hiddens, head_cfg, n_points=512,
        distill_cfg=DistillConfig(n_steps=2000, lr=5e-3))
    print(f"   distill MSE: {metrics['final_mse']:.5f}")

    print("2. freezing → SketchHead(backend='fused'), saving deployable head …")
    head = SketchHead(
        cfg=head_cfg, backend="fused",
        params=freeze_head(jax.random.PRNGKey(4), kparams, head_cfg))
    head.save(HEAD_PATH)
    print(f"   saved {HEAD_PATH} (kind + backend round-trip with the file)")
    print("   (the head is tied to this example's 512-vocab variant; "
          "repro.launch.serve --sketch-head --head-path validates the "
          "arch/head shapes and distills a fresh head when none is given)")

    test_h = jax.random.normal(jax.random.PRNGKey(5), (256, cfg.d_model))
    dense_logits = test_h @ np.asarray(table, np.float32).T
    sketch_logits = head.apply(head.params, test_h)

    top5_dense = np.argsort(-dense_logits, 1)[:, :5]
    top1_sketch = np.asarray(jnp.argmax(sketch_logits, 1))
    in_top5 = np.mean([t in top5_dense[i]
                       for i, t in enumerate(top1_sketch)])
    print(f"   sketch-head top-1 ∈ dense top-5: {in_top5:.2%}")

    print("3. serving: LM.generate through the fused sketch head …")
    lm = LM(params, cfg, head)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 12), 0,
                                 cfg.vocab_size)
    out = lm.generate(prompts, 8)
    print(f"   generated {out.shape} tokens; sample:",
          np.asarray(out[0, -8:]))

    print("4. engine: LM.serve of a staggered request stream through the "
          "reloaded head …")
    loaded = load_head(HEAD_PATH)   # dispatches on the stored kind/backend
    print(f"   loaded {loaded.describe()} head "
          f"(L={loaded.cfg.n_rows}, R={loaded.cfg.n_buckets})")
    rng = np.random.default_rng(7)
    requests = [(rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
                 int(rng.integers(2, 9)), 2 * i) for i in range(5)]
    engine = lm.with_head(loaded).engine(n_slots=2, max_seq=20)
    for prompt, max_new, arrival in requests:
        engine.submit(prompt, max_new, arrival=arrival)
    finished = engine.run()
    print(f"   {len(finished)} requests retired over 2 recycled slots, "
          f"slot utilization {engine.slot_utilization:.2f}; "
          f"lengths: {sorted(len(v) for v in finished.values())}")

    costs = head_costs(head_cfg, cfg.d_model, cfg.vocab_size)
    print(f"   params: {costs['param_ratio']:.2f}x reduction, "
          f"flops/token: {costs['flop_ratio']:.2f}x reduction")
    print("   (vocab≈d_model here, so gains are modest — see DESIGN.md §4; "
          "for a 100k-vocab head the same L gives "
          f"{head_costs(head_cfg, 4096, 100352)['flop_ratio']:.0f}x)")


if __name__ == "__main__":
    main()
