"""Quickstart: weighted RACE sketch in 40 lines, via the ``repro.api``
facade.

Builds a sketch over weighted points, queries it, and compares against the
exact weighted kernel density — Algorithm 1 + 2 of the paper end to end.
(The same facade serves models: ``LM.from_config(...).generate(...)`` — see
examples/serve_sketch_head.py and DESIGN.md §8.)

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.api import RepresenterSketch, SketchConfig


def main():
    cfg = SketchConfig(
        n_rows=500,        # L — rows (more rows → lower variance, Thm 2)
        n_buckets=16,      # R — counters per row
        k=2,               # concatenated hashes per row (sharper kernel)
        dim=8,             # input dimensionality
        n_outputs=1,
        bandwidth=2.0,     # r — p-stable quantization width
        n_groups=8,        # g — median-of-means groups
    )
    sketch = RepresenterSketch(cfg)

    key = jax.random.PRNGKey(0)
    kp, ka, kq, ks = jax.random.split(key, 4)
    points = jax.random.normal(kp, (1000, cfg.dim))   # dataset U
    alphas = jax.random.normal(ka, (1000, 1))         # weights α_i
    queries = jax.random.normal(kq, (5, cfg.dim))

    state = sketch.init(ks)                    # L hash fns + zero array
    state = sketch.build(state, points, alphas)        # Algorithm 1

    est = sketch.query(state, queries)                 # Algorithm 2 (MoM)
    exact = sketch.exact_weighted_kde(points, alphas, queries)

    print(f"sketch storage: {cfg.memory_floats} floats "
          f"({cfg.memory_floats * 4 / 1024:.1f} KiB) vs "
          f"{points.size + alphas.size} floats for raw data")
    for i in range(queries.shape[0]):
        print(f"  query {i}: sketch={float(est[i, 0]):8.3f}   "
              f"exact={float(exact[i, 0]):8.3f}")


if __name__ == "__main__":
    main()
