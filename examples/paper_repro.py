"""Paper reproduction driver: one tabular dataset through the whole recipe.

  NN teacher  →  weighted-kernel student (distilled)  →  Representer Sketch

Reports the Table-1 row for the chosen dataset (accuracy parity + memory
and FLOP reductions).

  PYTHONPATH=src python examples/paper_repro.py --dataset adult
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # for benchmarks/

from benchmarks.table1_repro import FAST, run_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="adult",
                    choices=["adult", "phishing", "skin", "susy", "abalone",
                             "yearmsd"])
    args = ap.parse_args()
    r = run_dataset(args.dataset, FAST)
    metric = "accuracy" if r["task"] == "classification" else "MAE"
    print(f"\ndataset={r['dataset']}  ({r['task']}, metric={metric})")
    print(f"  NN     : {r['nn']:.4f}   ({r['nn_mem_mb']:.3f} MB, "
          f"{r['nn_flops'] / 1e3:.1f}K FLOPs/query)")
    print(f"  Kernel : {r['kernel']:.4f}")
    print(f"  Sketch : {r['rs']:.4f}   ({r['rs_mem_mb']:.3f} MB, "
          f"{r['rs_flops'] / 1e3:.1f}K FLOPs/query)")
    print(f"  memory reduction {r['mem_reduction']:.1f}x, "
          f"FLOP reduction {r['flop_reduction']:.1f}x")


if __name__ == "__main__":
    main()
