"""Programmatic multi-pod dry-run of a single cell.

Shows the launcher API: build the production mesh from placeholder devices,
lower + compile one (arch × shape), and read the roofline inputs off the
compiled artifact.  The XLA flag must precede any jax import — run this as
a script, not inside an initialized process.

  PYTHONPATH=src python examples/multi_pod_dryrun.py --arch gemma2-27b \
      --shape prefill_32k --mesh multi
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi"])
    args = ap.parse_args()
    r = run_cell(args.arch, args.shape, args.mesh, save=False)
    print(f"\nHLO dot FLOPs / device : {r['flops']:.3e}")
    print(f"bytes accessed / device: {r['bytes_accessed']:.3e}")
    print(f"collective bytes       : {r['collective_bytes']}")
    print(f"temp bytes / device    : "
          f"{r['memory_analysis']['temp_size_bytes']:,}")


if __name__ == "__main__":
    main()
