"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the granite family config scaled to ~100M params, the full substrate
(sharded step, prefetching loader, async checkpointing, straggler tracker)
on whatever devices exist.  Loss is asserted to decrease.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, PrefetchingLoader
from repro.launch.steps import train_step
from repro.models.config import AttentionConfig, param_count
from repro.models.model import init_model
from repro.optim.adamw import OptimizerConfig, init_adamw


def lm_100m():
    base = get_config("granite-8b")
    return dataclasses.replace(
        base, name="lm-100m", n_layers=8, d_model=640, d_ff=1792,
        vocab_size=32768, tie_embeddings=True,
        attention=AttentionConfig(n_heads=10, n_kv_heads=2, head_dim=64))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    print(f"model: {cfg.name}, {param_count(cfg) / 1e6:.1f}M params")
    opt_cfg = OptimizerConfig(lr=6e-4, warmup_steps=args.steps // 20 + 1,
                              total_steps=args.steps)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    loader = PrefetchingLoader(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    step_fn = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
                      donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        _, batch = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0:
            rate = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({rate:.0f} tok/s)")
        if step and step % 100 == 0:
            ckpt.save(step, jax.tree.map(np.asarray, (params, opt)))
    ckpt.wait()
    loader.close()

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss: {first:.4f} → {last:.4f} over {args.steps} steps "
          f"({time.time() - t0:.0f}s)")
    assert last < first, "training must reduce loss"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
