"""Synthetic UCI-style tabular datasets for the paper reproduction.

The paper evaluates on UCI/libsvm tasks (Adult, phishing, skin, SUSY,
abalone, YearMSD).  Those files are not available offline, so we generate
synthetic datasets with matching (n_features, task type, approximate size)
and — crucially — *learnable nonlinear structure* so the NN → kernel → sketch
pipeline faces a realistic function.  Ground truth is a random shallow
teacher with interactions + threshold nonlinearities.

This keeps the paper's protocol intact: train an MLP, distill it into the
weighted LSH-kernel representation, sketch it, and compare
accuracy/memory/FLOPs.  Absolute accuracies differ from the paper's (the
data differ); the *relative* claims (Kernel ≈ NN, RS ≈ Kernel, 17–114×
memory reduction at parity) are what benchmarks/table1_repro.py reproduces.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TabularSpec:
    name: str
    n_features: int
    n_train: int
    n_test: int
    task: str                 # 'classification' (binary) | 'regression'
    nn_hidden: Tuple[int, ...]  # paper Table 2 architecture
    rs_R: int                 # paper Table 2 sketch params
    rs_K: int


# Paper Table 2 settings, sizes scaled to run in CI minutes on 1 CPU core.
DATASETS: Dict[str, TabularSpec] = {
    "adult":    TabularSpec("adult", 123, 20000, 5000, "classification",
                            (512, 256, 128), 500, 1),
    "phishing": TabularSpec("phishing", 68, 8000, 2000, "classification",
                            (512, 256, 128), 300, 3),
    "skin":     TabularSpec("skin", 3, 20000, 5000, "classification",
                            (256, 128, 64), 300, 3),
    "susy":     TabularSpec("susy", 18, 20000, 5000, "classification",
                            (1024, 512, 256, 128, 64), 1000, 2),
    "abalone":  TabularSpec("abalone", 8, 3300, 800, "regression",
                            (256, 128), 300, 1),
    "yearmsd":  TabularSpec("yearmsd", 90, 20000, 5000, "regression",
                            (1024, 512, 256, 128), 500, 3),
}


def make_dataset(spec: TabularSpec, seed: int = 0):
    """Generate (x_train, y_train, x_test, y_test) float32/int32 arrays."""
    rng = np.random.default_rng(seed + hash(spec.name) % (2**31))
    n = spec.n_train + spec.n_test
    x = rng.standard_normal((n, spec.n_features)).astype(np.float32)
    # Sparse binary-ish features for high-dim sets (UCI libsvm style).
    if spec.n_features > 50:
        x = (x > 0.8).astype(np.float32)

    # Random shallow teacher: interactions + thresholds.
    w1 = rng.standard_normal((spec.n_features, 32)) / np.sqrt(spec.n_features)
    b1 = rng.standard_normal(32) * 0.5
    w2 = rng.standard_normal(32)
    h = np.tanh(x @ w1 + b1)
    score = h @ w2 + 0.5 * (h[:, 0] * h[:, 1]) + 0.25 * np.abs(h[:, 2])

    if spec.task == "classification":
        y = (score > np.median(score)).astype(np.int32)
        # 5% label noise like real tabular data.
        flip = rng.random(n) < 0.05
        y = np.where(flip, 1 - y, y)
    else:
        noise = rng.standard_normal(n) * 0.1 * score.std()
        y = (score + noise).astype(np.float32)

    tr, te = spec.n_train, spec.n_test
    return x[:tr], y[:tr], x[tr:tr + te], y[tr:tr + te]
