"""Deterministic synthetic token pipeline with sharded host loading.

Production shape: each host materializes only its shard of the global batch
(``host_slice``), tokens are generated from a counter-based hash (stateless,
reproducible, seekable — restart at step N reproduces the same batch without
replaying N steps), and an async prefetch thread keeps ``prefetch`` batches
ready.  A real deployment swaps ``synthetic_batch`` for a tokenized-shard
reader behind the same iterator contract.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    n_encoder_tokens: int = 0
    d_model: int = 0          # for encoder-state stubs


def _counter_hash(counters: np.ndarray, seed: int) -> np.ndarray:
    """Stateless splitmix-style integer hash (uint64 → uint64)."""
    x = counters.astype(np.uint64) + np.uint64(seed * 0x9E3779B97F4A7C15 + 1)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for ``step`` — this host's slice only.

    Token stream has Zipf-ish marginals + a short-range copy structure so the
    LM loss is learnable (tests assert loss decreases).
    """
    per_host = cfg.global_batch // cfg.n_hosts
    base = (np.int64(step) * cfg.global_batch + cfg.host_id * per_host)
    rows = base + np.arange(per_host, dtype=np.int64)[:, None]
    cols = np.arange(cfg.seq_len + 1, dtype=np.int64)[None, :]
    h = _counter_hash(rows * (cfg.seq_len + 1) + cols, cfg.seed)
    # Zipf-ish marginal: square a uniform to skew towards low ids.
    u = (h % np.uint64(1 << 30)).astype(np.float64) / float(1 << 30)
    toks = (u * u * cfg.vocab_size).astype(np.int32)
    # Copy structure: every 8th position repeats position-4 tokens.
    toks[:, 8::8] = toks[:, 4:-4:8][:, : toks[:, 8::8].shape[1]]
    batch = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
    }
    if cfg.n_encoder_tokens:
        he = _counter_hash(
            rows * np.int64(cfg.n_encoder_tokens * cfg.d_model)
            + np.arange(cfg.n_encoder_tokens * cfg.d_model, dtype=np.int64)[None, :],
            cfg.seed + 1)
        enc = ((he % np.uint64(1 << 16)).astype(np.float32) / (1 << 15) - 1.0)
        batch["encoder_states"] = enc.reshape(
            per_host, cfg.n_encoder_tokens, cfg.d_model).astype(np.float32)
    return batch


class PrefetchingLoader:
    """Background-thread prefetch over synthetic_batch (host-local shard)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = synthetic_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
