"""Sampler: one hashable object for the whole decode-time sampling policy.

Replaces the ``greedy: bool`` + ``seed: int`` pair that used to thread
positionally through ``generate()`` and the engine.  A ``Sampler`` is a
frozen dataclass, so it can key jit memo caches; its ``sample`` method is
jitted once per distinct sampler.

Semantics:

* ``temperature == 0``  → greedy argmax (the default); the key is untouched.
* ``temperature > 0``   → softmax sampling at that temperature, after
  optional ``top_k`` (keep the k largest logits) and ``top_p`` (smallest
  nucleus whose probability mass ≥ p) filtering.
* The PRNG is a *key chain* seeded once from ``seed``: every step splits the
  carried key, so runs with the same seed reproduce bitwise and different
  seeds give independent streams.  ``Sampler(temperature=1.0, seed=s)``
  reproduces the pre-redesign ``greedy=False, seed=s`` token streams
  exactly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Sampler:
    """The decode-time sampling policy as one hashable spec.

    Attributes:
      temperature: 0 → greedy argmax (default); > 0 → softmax sampling.
      top_k: keep only the k largest logits (0 disables).
      top_p: keep the smallest nucleus with probability mass ≥ p
        (1.0 disables).
      seed: PRNG seed for the per-run key chain.

    Raises:
      ValueError: on a negative temperature / top_k, or top_p ∉ (0, 1].

    >>> Sampler().describe()
    'greedy'
    >>> Sampler(temperature=0.8, top_k=40, seed=1).describe()
    'sample(t=0.8,top_k=40,seed=1)'
    >>> Sampler(top_p=0)
    Traceback (most recent call last):
        ...
    ValueError: top_p must be in (0, 1], got 0
    """

    temperature: float = 0.0   # 0 → greedy
    top_k: int = 0             # 0 → no top-k filter
    top_p: float = 1.0         # 1 → no nucleus filter
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @classmethod
    def greedy(cls) -> "Sampler":
        """The greedy policy (equivalent to ``Sampler()``)."""
        return cls()

    @property
    def is_greedy(self) -> bool:
        """True when ``temperature == 0`` (argmax; PRNG never consumed)."""
        return self.temperature == 0.0

    def init_key(self) -> jax.Array:
        """The root of this sampler's split-key chain (from ``seed``)."""
        return jax.random.PRNGKey(self.seed)

    def sample(self, key: jax.Array,
               logits: jnp.ndarray) -> Tuple[jax.Array, jnp.ndarray]:
        """Pick one token per row.

        Args:
          key: the carried chain key (start from :meth:`init_key`).
          logits: (B, V) logits.

        Returns:
          ``(next_key, tokens)`` — the advanced chain key (untouched when
          greedy) and (B,) int32 token ids.  Jitted once per distinct
          sampler spec.
        """
        return _jitted_sample(self)(key, jnp.asarray(logits))

    def describe(self) -> str:
        """Short human-readable policy summary (see class doctest)."""
        if self.is_greedy:
            return "greedy"
        parts = [f"t={self.temperature:g}"]
        if self.top_k:
            parts.append(f"top_k={self.top_k}")
        if self.top_p < 1.0:
            parts.append(f"top_p={self.top_p:g}")
        return f"sample({','.join(parts)},seed={self.seed})"


def _filter_logits(sampler: Sampler, logits: jnp.ndarray) -> jnp.ndarray:
    """Apply top-k then top-p in f32; untouched logits stay bitwise as-is."""
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    if sampler.top_k and sampler.top_k < logits.shape[-1]:
        kth = jnp.sort(logits, axis=-1)[..., -sampler.top_k][..., None]
        logits = jnp.where(logits < kth, neg, logits)
    if sampler.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]   # descending
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # Keep the smallest prefix with mass >= top_p (always >= 1 token):
        # a token is cut iff the mass *before* it already reached top_p.
        cut = cum - probs >= sampler.top_p
        # Threshold on the smallest *kept* logit: a cut token tied with it
        # also survives (thresholding by value cannot split ties, and
        # masking the tie would mask the kept token with it, emptying the
        # row); anything strictly below the nucleus is dropped.
        keep_min = jnp.where(cut, jnp.inf, sorted_logits).min(axis=-1,
                                                              keepdims=True)
        logits = jnp.where(logits < keep_min, neg, logits)
    return logits


def _sample_impl(sampler: Sampler, key: jax.Array, logits: jnp.ndarray):
    if sampler.is_greedy:
        return key, jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key, sub = jax.random.split(key)
    if sampler.temperature != 1.0:
        logits = logits / sampler.temperature
    if sampler.top_k or sampler.top_p < 1.0:
        logits = _filter_logits(sampler, logits)
    return key, jax.random.categorical(sub, logits).astype(jnp.int32)


@functools.lru_cache(maxsize=None)
def _jitted_sample(sampler: Sampler):
    """One compiled sampler per distinct Sampler spec (hashable memo key)."""
    return jax.jit(functools.partial(_sample_impl, sampler))
