"""First-class logit heads: the ``LogitHead`` registry (DESIGN.md §8).

The paper's pitch is that a Representer Sketch is a *drop-in replacement*
for the dense inference path.  This module makes the swap an object, not a
flag: a ``LogitHead`` is a hashable spec of how decode-time logits are
produced — its *kind* (``dense`` / ``sketch``), its kernel *backend*
(``fused`` / ``two_kernel`` / ``ref``), and, for heads with state, the
frozen arrays.  Head specs key the jitted-step memo cache
(``launch.steps.jitted_serve_fns``); the arrays ride along as a runtime
argument so two heads that compile identically share one executable.

Adding a third head kind is one ``@register_head`` class — no call-site
edits in launch/, examples/, or benchmarks/.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Type

import jax.numpy as jnp

from repro.core.sketch_lm_head import HEAD_BACKENDS as SKETCH_BACKENDS
from repro.core.sketch_lm_head import QUANT_MODES
from repro.models.config import SketchHeadConfig

HEAD_KINDS: Dict[str, Type["LogitHead"]] = {}


def register_head(kind: str):
    """Class decorator: register a LogitHead subclass under ``kind``.

    Args:
      kind: the registry key; becomes the head's persisted ``meta_kind`` so
        ``load_head`` can dispatch on it.

    Returns:
      The decorating function (returns the class unchanged).

    Example:

    >>> @register_head("null")
    ... class NullHead(LogitHead):
    ...     kind = "null"
    >>> get_head_class("null") is NullHead
    True
    >>> del HEAD_KINDS["null"]  # keep the registry clean for other tests
    """

    def deco(cls):
        HEAD_KINDS[kind] = cls
        return cls

    return deco


def get_head_class(kind: str) -> Type["LogitHead"]:
    """The registered LogitHead subclass for ``kind``.

    Args:
      kind: a registry key (``"dense"``, ``"sketch"``, or a custom kind).

    Returns:
      The class registered under ``kind``.

    Raises:
      KeyError: if ``kind`` was never registered.

    >>> get_head_class("dense").__name__
    'DenseHead'
    """
    if kind not in HEAD_KINDS:
        raise KeyError(
            f"unknown head kind {kind!r}; registered: {sorted(HEAD_KINDS)}")
    return HEAD_KINDS[kind]


@dataclasses.dataclass(frozen=True)
class LogitHead:
    """Base spec: hashable, equality on static config only.

    ``needs_hidden`` tells ``serve_step`` whether the backbone should return
    the final hidden (head produces logits) or run its own dense unembed.
    ``params`` (on stateful heads) is excluded from hash/eq so the spec can
    key jit memo caches; always pass ``head.params`` as a runtime argument.
    """

    kind = "abstract"
    needs_hidden = False
    params = None  # stateless by default

    def apply(self, params: Any, hidden: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
        """Produce (B, V) logits from (B, d_model) final hiddens.

        Args:
          params: the head's runtime arrays (``head.params`` passed per call
            so the spec stays hashable).
          hidden: (B, d_model) final backbone hidden states.
          mesh: optional ``jax.sharding.Mesh`` for the sharded decode path;
            stateless heads may ignore it.

        Returns:
          (B, V) f32 logits.

        Raises:
          NotImplementedError: on the abstract base.
        """
        raise NotImplementedError

    def without_params(self) -> "LogitHead":
        """The bare spec — what jit memo caches should key on."""
        return self

    def with_params(self, params: Any) -> "LogitHead":
        """This spec with runtime arrays attached.

        Args:
          params: the runtime arrays (``None`` allowed on stateless heads).

        Returns:
          A head carrying ``params``.

        Raises:
          ValueError: if a stateless head is given non-``None`` params.
        """
        if params is not None:
            raise ValueError(f"{type(self).__name__} is stateless")
        return self

    def describe(self) -> str:
        """Short human-readable identity (kind, plus backend if any)."""
        return self.kind


@register_head("dense")
@dataclasses.dataclass(frozen=True)
class DenseHead(LogitHead):
    """The backbone's own ``h · Wᵀ`` unembed — logits come straight out of
    ``decode_step``; this head carries no state and applies nothing."""

    kind = "dense"
    needs_hidden = False

    def apply(self, params, hidden, mesh=None):
        """Never called — dense logits come out of the backbone.

        Raises:
          RuntimeError: always; ``serve_step`` must not route a DenseHead
            through ``apply``.
        """
        raise RuntimeError(
            "DenseHead logits come from the backbone's unembed; "
            "serve_step should not call apply()")


@register_head("sketch")
@dataclasses.dataclass(frozen=True)
class SketchHead(LogitHead):
    """The Representer-Sketch head: frozen (proj, w, b, array) params plus a
    decode backend.

    ``backend``:
      * ``"fused"``      — one pallas_call: transform → hash → gather
                           (repro.kernels.fused_decode; the serving default),
      * ``"two_kernel"`` — lsh_hash → sketch_head composition (the unfused
                           baseline, (B, L) indices round-trip through HBM),
      * ``"ref"``        — the pure-jnp oracle composition (CPU/CI parity).

    The kernel-level pallas/ref choice *within* ``fused``/``two_kernel`` is
    the kernel registry's (``REPRO_KERNEL_BACKEND``, DESIGN.md §8).

    On a serving mesh (``LM.from_config(mesh=...)``), ``apply`` runs the
    shard_map path: count arrays partitioned over ``model`` on the
    repetition axis, one psum per decode step (DESIGN.md §9).

    ``quant`` declares the count-array storage (``None`` = f32,
    ``"int8"``/``"int4"`` = per-row symmetric quantized with an extra
    ``"scale"`` leaf in ``params``; DESIGN.md §12).  It is a *compare*
    field: an int8 head and an f32 head of the same config are different
    specs and compile different kernels, so the jit memo caches
    (``launch.steps.jitted_serve_fns``) key on it automatically.

    >>> SketchHead(backend="ref").describe()
    'sketch/ref'
    >>> SketchHead(quant="int8").describe()
    'sketch/fused/int8'
    >>> SketchHead().with_backend("two_kernel").backend
    'two_kernel'
    >>> SketchHead(backend="nope")
    Traceback (most recent call last):
        ...
    ValueError: unknown sketch-head backend 'nope'; expected one of ('fused', 'two_kernel', 'ref')
    """

    kind = "sketch"
    needs_hidden = True

    cfg: SketchHeadConfig = dataclasses.field(default_factory=SketchHeadConfig)
    backend: str = "fused"
    quant: Optional[str] = None
    params: Optional[dict] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.backend not in SKETCH_BACKENDS:
            raise ValueError(
                f"unknown sketch-head backend {self.backend!r}; "
                f"expected one of {SKETCH_BACKENDS}")
        if self.quant not in QUANT_MODES:
            raise ValueError(
                f"unknown sketch-head quant mode {self.quant!r}; "
                f"expected one of {QUANT_MODES}")

    def apply(self, params: dict, hidden: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
        """Sketched (B, V) logits for (B, d_model) hiddens.

        Args:
          params: the frozen head arrays ({"proj", "w", "b", "array"}).
          hidden: (B, d_model) final backbone hidden states.
          mesh: optional serving mesh; with a ``model`` axis the count
            arrays evaluate shard-locally and reduce with one psum.

        Returns:
          (B, V) f32 logits on this spec's ``backend``.

        Raises:
          ValueError: if ``params`` is None (a bare spec cannot serve).
        """
        from repro.core.sketch_lm_head import apply_head
        if params is None:
            raise ValueError(
                "SketchHead.apply needs the frozen head params; build them "
                "with freeze_head/distill_head or load them with "
                "SketchHead.load")
        return apply_head(params, hidden, self.cfg, backend=self.backend,
                          quant=self.quant, mesh=mesh)

    def without_params(self) -> "SketchHead":
        """The bare spec — what jit memo caches should key on."""
        if self.params is None:
            return self
        return dataclasses.replace(self, params=None)

    def with_params(self, params: dict) -> "SketchHead":
        """This spec with the frozen arrays attached (runtime identity)."""
        return dataclasses.replace(self, params=params)

    def with_backend(self, backend: str) -> "SketchHead":
        """The same head decoding on a different backend.

        Args:
          backend: one of ``"fused"`` / ``"two_kernel"`` / ``"ref"``.

        Returns:
          A new spec; raises ``ValueError`` (via ``__post_init__``) on an
          unknown backend name.
        """
        return dataclasses.replace(self, backend=backend)

    def quantized(self, quant: Optional[str]) -> "SketchHead":
        """This head with its count array quantized to ``quant`` storage.

        Args:
          quant: ``"int8"`` / ``"int4"`` (per-row symmetric, DESIGN.md §12)
            or ``None`` for a no-op on an f32 head.

        Returns:
          A new spec; when params are attached they are quantized in the
          same step (``quantize_head``), so the result serves immediately.

        Raises:
          ValueError: if this head is already quantized (re-quantization
            would compound rounding error; dequantize first) — unless
            ``quant`` equals the current mode, which is a no-op.
        """
        if quant == self.quant:
            return self
        if self.quant is not None:
            raise ValueError(
                f"head is already {self.quant}-quantized; cannot "
                f"re-quantize to {quant!r}")
        from repro.core.sketch_lm_head import quantize_head
        params = (quantize_head(self.params, quant)
                  if self.params is not None else None)
        return dataclasses.replace(self, quant=quant, params=params)

    def describe(self) -> str:
        """``"sketch/<backend>[/<quant>]"`` — the registry identity."""
        base = f"sketch/{self.backend}"
        return base if self.quant is None else f"{base}/{self.quant}"

    # -- persistence (round-trips kind + backend, DESIGN.md §8) ------------

    def save(self, path) -> None:
        """Persist params + config + registry identity as an .npz archive.

        Args:
          path: destination file path (parent dirs are created).

        Raises:
          ValueError: if the spec carries no params.
        """
        from repro.core.sketch_lm_head import save_head
        if self.params is None:
            raise ValueError("cannot save a SketchHead without params")
        save_head(path, self.params, self.cfg,
                  kind=self.kind, backend=self.backend, quant=self.quant)

    @classmethod
    def load(cls, path) -> "SketchHead":
        """Load a head saved by :meth:`save` (kind/backend round-trip).

        Args:
          path: the .npz archive.

        Returns:
          A ready-to-serve ``SketchHead`` on the backend it was saved with
          (archives predating the metadata load as ``fused``).
        """
        from repro.core.sketch_lm_head import load_head_full
        params, cfg, meta = load_head_full(path)
        return cls(cfg=cfg, backend=meta["backend"], quant=meta["quant"],
                   params=params)


def load_head(path) -> LogitHead:
    """Load any saved head; dispatches on the stored ``kind`` metadata.

    Args:
      path: an .npz archive written by a head's ``save``.

    Returns:
      An instance of the registered class for the stored kind, with params
      attached.

    Raises:
      KeyError: if the stored kind was never registered in this process.
      TypeError: if the registered class has no ``load``.
    """
    from repro.core.sketch_lm_head import load_head_meta
    kind = load_head_meta(path)["kind"]
    cls = get_head_class(kind)
    if not hasattr(cls, "load"):
        raise TypeError(f"head kind {kind!r} does not support load()")
    return cls.load(path)
