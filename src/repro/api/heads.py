"""First-class logit heads: the ``LogitHead`` registry (DESIGN.md §8).

The paper's pitch is that a Representer Sketch is a *drop-in replacement*
for the dense inference path.  This module makes the swap an object, not a
flag: a ``LogitHead`` is a hashable spec of how decode-time logits are
produced — its *kind* (``dense`` / ``sketch``), its kernel *backend*
(``fused`` / ``two_kernel`` / ``ref``), and, for heads with state, the
frozen arrays.  Head specs key the jitted-step memo cache
(``launch.steps.jitted_serve_fns``); the arrays ride along as a runtime
argument so two heads that compile identically share one executable.

Adding a third head kind is one ``@register_head`` class — no call-site
edits in launch/, examples/, or benchmarks/.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Type

import jax.numpy as jnp

from repro.core.sketch_lm_head import HEAD_BACKENDS as SKETCH_BACKENDS
from repro.core.sketch_lm_head import QUANT_MODES
from repro.models.config import SketchHeadConfig

HEAD_KINDS: Dict[str, Type["LogitHead"]] = {}


def register_head(kind: str):
    """Class decorator: register a LogitHead subclass under ``kind``.

    Args:
      kind: the registry key; becomes the head's persisted ``meta_kind`` so
        ``load_head`` can dispatch on it.

    Returns:
      The decorating function (returns the class unchanged).

    Example:

    >>> @register_head("null")
    ... class NullHead(LogitHead):
    ...     kind = "null"
    >>> get_head_class("null") is NullHead
    True
    >>> del HEAD_KINDS["null"]  # keep the registry clean for other tests
    """

    def deco(cls):
        HEAD_KINDS[kind] = cls
        return cls

    return deco


def get_head_class(kind: str) -> Type["LogitHead"]:
    """The registered LogitHead subclass for ``kind``.

    Args:
      kind: a registry key (``"dense"``, ``"sketch"``, or a custom kind).

    Returns:
      The class registered under ``kind``.

    Raises:
      KeyError: if ``kind`` was never registered.

    >>> get_head_class("dense").__name__
    'DenseHead'
    """
    if kind not in HEAD_KINDS:
        raise KeyError(
            f"unknown head kind {kind!r}; registered: {sorted(HEAD_KINDS)}")
    return HEAD_KINDS[kind]


@dataclasses.dataclass(frozen=True)
class LogitHead:
    """Base spec: hashable, equality on static config only.

    ``needs_hidden`` tells ``serve_step`` whether the backbone should return
    the final hidden (head produces logits) or run its own dense unembed.
    ``params`` (on stateful heads) is excluded from hash/eq so the spec can
    key jit memo caches; always pass ``head.params`` as a runtime argument.
    """

    kind = "abstract"
    needs_hidden = False
    params = None  # stateless by default

    def apply(self, params: Any, hidden: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
        """Produce (B, V) logits from (B, d_model) final hiddens.

        Args:
          params: the head's runtime arrays (``head.params`` passed per call
            so the spec stays hashable).
          hidden: (B, d_model) final backbone hidden states.
          mesh: optional ``jax.sharding.Mesh`` for the sharded decode path;
            stateless heads may ignore it.

        Returns:
          (B, V) f32 logits.

        Raises:
          NotImplementedError: on the abstract base.
        """
        raise NotImplementedError

    def without_params(self) -> "LogitHead":
        """The bare spec — what jit memo caches should key on."""
        return self

    def with_params(self, params: Any) -> "LogitHead":
        """This spec with runtime arrays attached.

        Args:
          params: the runtime arrays (``None`` allowed on stateless heads).

        Returns:
          A head carrying ``params``.

        Raises:
          ValueError: if a stateless head is given non-``None`` params.
        """
        if params is not None:
            raise ValueError(f"{type(self).__name__} is stateless")
        return self

    def describe(self) -> str:
        """Short human-readable identity (kind, plus backend if any)."""
        return self.kind


@register_head("dense")
@dataclasses.dataclass(frozen=True)
class DenseHead(LogitHead):
    """The backbone's own ``h · Wᵀ`` unembed — logits come straight out of
    ``decode_step``; this head carries no state and applies nothing."""

    kind = "dense"
    needs_hidden = False

    def apply(self, params, hidden, mesh=None):
        """Never called — dense logits come out of the backbone.

        Raises:
          RuntimeError: always; ``serve_step`` must not route a DenseHead
            through ``apply``.
        """
        raise RuntimeError(
            "DenseHead logits come from the backbone's unembed; "
            "serve_step should not call apply()")


@register_head("sketch")
@dataclasses.dataclass(frozen=True)
class SketchHead(LogitHead):
    """The Representer-Sketch head: frozen (proj, w, b, array) params plus a
    decode backend.

    ``backend``:
      * ``"fused"``      — one pallas_call: transform → hash → gather
                           (repro.kernels.fused_decode; the serving default),
      * ``"two_kernel"`` — lsh_hash → sketch_head composition (the unfused
                           baseline, (B, L) indices round-trip through HBM),
      * ``"ref"``        — the pure-jnp oracle composition (CPU/CI parity).

    The kernel-level pallas/ref choice *within* ``fused``/``two_kernel`` is
    the kernel registry's (``REPRO_KERNEL_BACKEND``, DESIGN.md §8).

    On a serving mesh (``LM.from_config(mesh=...)``), ``apply`` runs the
    shard_map path: count arrays partitioned over ``model`` on the
    repetition axis, one psum per decode step (DESIGN.md §9).

    ``quant`` declares the count-array storage (``None`` = f32,
    ``"int8"``/``"int4"`` = per-row symmetric quantized with an extra
    ``"scale"`` leaf in ``params``; DESIGN.md §12).  It is a *compare*
    field: an int8 head and an f32 head of the same config are different
    specs and compile different kernels, so the jit memo caches
    (``launch.steps.jitted_serve_fns``) key on it automatically.

    ``per_tenant`` (also compare — it changes the compiled gather) declares
    the multi-tenant binding (DESIGN.md §14): runtime ``params`` is a
    tenant-stacked bank (leading axis T on every array leaf, built by
    ``core.sketch_lm_head.stack_heads`` / served from a :class:`HeadCache`)
    plus a ``"tenant_ids"`` (B,) int32 leaf mapping each batch slot to its
    tenant's bank row.  Decode computes every resident tenant's full-batch
    logits on the unmodified single-tenant path and row-selects
    arithmetic-free, so each slot's stream is bitwise what a single-tenant
    engine bound to that tenant's head emits.

    >>> SketchHead(backend="ref").describe()
    'sketch/ref'
    >>> SketchHead(quant="int8").describe()
    'sketch/fused/int8'
    >>> SketchHead(per_tenant=True).describe()
    'sketch/fused/tenants'
    >>> SketchHead().with_backend("two_kernel").backend
    'two_kernel'
    >>> SketchHead(backend="nope")
    Traceback (most recent call last):
        ...
    ValueError: unknown sketch-head backend 'nope'; expected one of ('fused', 'two_kernel', 'ref')
    """

    kind = "sketch"
    needs_hidden = True

    cfg: SketchHeadConfig = dataclasses.field(default_factory=SketchHeadConfig)
    backend: str = "fused"
    quant: Optional[str] = None
    per_tenant: bool = False
    params: Optional[dict] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.backend not in SKETCH_BACKENDS:
            raise ValueError(
                f"unknown sketch-head backend {self.backend!r}; "
                f"expected one of {SKETCH_BACKENDS}")
        if self.quant not in QUANT_MODES:
            raise ValueError(
                f"unknown sketch-head quant mode {self.quant!r}; "
                f"expected one of {QUANT_MODES}")

    def apply(self, params: dict, hidden: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
        """Sketched (B, V) logits for (B, d_model) hiddens.

        Args:
          params: the frozen head arrays ({"proj", "w", "b", "array"}); on a
            ``per_tenant`` spec, the tenant-stacked bank with a
            ``"tenant_ids"`` (B,) int32 leaf (``HeadCache.bank_params``).
          hidden: (B, d_model) final backbone hidden states.
          mesh: optional serving mesh; with a ``model`` axis the count
            arrays evaluate shard-locally and reduce with one psum.

        Returns:
          (B, V) f32 logits on this spec's ``backend``.

        Raises:
          ValueError: if ``params`` is None (a bare spec cannot serve), or
            if a ``per_tenant`` spec's params carry no ``"tenant_ids"``.
        """
        from repro.core.sketch_lm_head import apply_head
        if params is None:
            raise ValueError(
                "SketchHead.apply needs the frozen head params; build them "
                "with freeze_head/distill_head or load them with "
                "SketchHead.load")
        if self.per_tenant:
            if "tenant_ids" not in params:
                raise ValueError(
                    "per_tenant SketchHead.apply needs a 'tenant_ids' leaf "
                    "in params — pass HeadCache.bank_params(slot_tenants)")
            bank = {k: v for k, v in params.items() if k != "tenant_ids"}
            return apply_head(bank, hidden, self.cfg, backend=self.backend,
                              quant=self.quant, mesh=mesh,
                              tenant_ids=params["tenant_ids"])
        return apply_head(params, hidden, self.cfg, backend=self.backend,
                          quant=self.quant, mesh=mesh)

    def without_params(self) -> "SketchHead":
        """The bare spec — what jit memo caches should key on."""
        if self.params is None:
            return self
        return dataclasses.replace(self, params=None)

    def with_params(self, params: dict) -> "SketchHead":
        """This spec with the frozen arrays attached (runtime identity)."""
        return dataclasses.replace(self, params=params)

    def with_backend(self, backend: str) -> "SketchHead":
        """The same head decoding on a different backend.

        Args:
          backend: one of ``"fused"`` / ``"two_kernel"`` / ``"ref"``.

        Returns:
          A new spec; raises ``ValueError`` (via ``__post_init__``) on an
          unknown backend name.
        """
        return dataclasses.replace(self, backend=backend)

    def quantized(self, quant: Optional[str]) -> "SketchHead":
        """This head with its count array quantized to ``quant`` storage.

        Args:
          quant: ``"int8"`` / ``"int4"`` (per-row symmetric, DESIGN.md §12)
            or ``None`` for a no-op on an f32 head.

        Returns:
          A new spec; when params are attached they are quantized in the
          same step (``quantize_head``), so the result serves immediately.

        Raises:
          ValueError: if this head is already quantized (re-quantization
            would compound rounding error; dequantize first) — unless
            ``quant`` equals the current mode, which is a no-op.
        """
        if quant == self.quant:
            return self
        if self.quant is not None:
            raise ValueError(
                f"head is already {self.quant}-quantized; cannot "
                f"re-quantize to {quant!r}")
        from repro.core.sketch_lm_head import quantize_head
        params = (quantize_head(self.params, quant)
                  if self.params is not None else None)
        return dataclasses.replace(self, quant=quant, params=params)

    def describe(self) -> str:
        """``"sketch/<backend>[/<quant>][/tenants]"`` — the registry
        identity."""
        base = f"sketch/{self.backend}"
        if self.quant is not None:
            base = f"{base}/{self.quant}"
        if self.per_tenant:
            base = f"{base}/tenants"
        return base

    # -- persistence (round-trips kind + backend, DESIGN.md §8) ------------

    def save(self, path) -> None:
        """Persist params + config + registry identity as an .npz archive.

        Args:
          path: destination file path (parent dirs are created).

        Raises:
          ValueError: if the spec carries no params.
        """
        from repro.core.sketch_lm_head import save_head
        if self.params is None:
            raise ValueError("cannot save a SketchHead without params")
        save_head(path, self.params, self.cfg,
                  kind=self.kind, backend=self.backend, quant=self.quant)

    @classmethod
    def from_archive(cls, params: dict, cfg: SketchHeadConfig,
                     meta: dict) -> "SketchHead":
        """Build a head from already-parsed archive contents.

        Args:
          params / cfg / meta: the ``load_head_full`` triple.

        Returns:
          A ready-to-serve ``SketchHead`` on the backend it was saved with
          (archives predating the metadata load as ``fused``).
        """
        return cls(cfg=cfg, backend=meta["backend"], quant=meta["quant"],
                   params=params)

    @classmethod
    def load(cls, path) -> "SketchHead":
        """Load a head saved by :meth:`save` (kind/backend round-trip).

        Args:
          path: the .npz archive.

        Returns:
          A ready-to-serve ``SketchHead`` on the backend it was saved with
          (archives predating the metadata load as ``fused``).
        """
        from repro.core.sketch_lm_head import load_head_full
        return cls.from_archive(*load_head_full(path))


def load_head(path) -> LogitHead:
    """Load any saved head; dispatches on the stored ``kind`` metadata.

    Opens the archive exactly once: ``load_head_full`` returns params,
    config, *and* metadata in one read, and the registered class rebuilds
    from that triple via ``from_archive``.  Classes without ``from_archive``
    fall back to ``cls.load(path)`` (a second open — acceptable for
    third-party kinds, never for the built-ins).

    Args:
      path: an .npz archive written by a head's ``save``.

    Returns:
      An instance of the registered class for the stored kind, with params
      attached.

    Raises:
      KeyError: if the stored kind was never registered in this process.
      TypeError: if the registered class has no ``load``/``from_archive``.
    """
    from repro.core.sketch_lm_head import load_head_full
    params, cfg, meta = load_head_full(path)
    cls = get_head_class(meta["kind"])
    if hasattr(cls, "from_archive"):
        return cls.from_archive(params, cfg, meta)
    if not hasattr(cls, "load"):
        raise TypeError(
            f"head kind {meta['kind']!r} does not support load()")
    return cls.load(path)


class HeadCache:
    """LRU pager for per-tenant sketch heads (DESIGN.md §14).

    Holds up to ``capacity`` tenants' frozen head params resident in a
    tenant-stacked *bank* (one stacked array per head leaf, leading axis =
    bank slot).  ``acquire`` pages a tenant in on miss via the ``loader``
    callback and pins it with a refcount — a tenant with live engine slots
    can never be evicted mid-decode; ``release`` unpins.  Eviction is LRU
    over unpinned tenants only; freed bank slots are reused
    lowest-index-first so replays are deterministic.

    ``publish`` overwrites a resident tenant's bank row in place — the
    double-buffered commit point of ``ServeEngine.refresh``: in-flight
    dispatches hold the old (immutable) bank arrays, the next tick reads
    the new ones.

    Not thread-safe; the serving engine drives it from one loop.
    """

    def __init__(self, loader, capacity: int, mesh=None):
        """Args:
          loader: ``loader(tenant) -> dict`` returning the tenant's frozen
            head params (e.g. ``lambda t: load_head(path_for(t)).params``).
            Every leaf must match the first-loaded head's shapes/dtypes.
          capacity: max resident tenants (bank slots); ≥ 1.
          mesh: optional serving mesh — the bank is placed with
            ``sharding.rules.head_bank_shardings`` so per-tenant rows
            shard exactly like a single-tenant head.
        """
        if capacity < 1:
            raise ValueError(f"HeadCache capacity must be >= 1, got "
                             f"{capacity}")
        self._loader = loader
        self.capacity = capacity
        self.mesh = mesh
        self._bank: Optional[dict] = None          # leaf -> (cap, …) array
        self._slot_of: Dict[Any, int] = {}         # tenant -> bank slot
        self._refs: Dict[Any, int] = {}            # tenant -> live pins
        self._lru: list = []                       # LRU→MRU among residents
        self.stats = {"hits": 0, "misses": 0, "loads": 0, "evictions": 0}

    # -- internal ----------------------------------------------------------

    def _init_bank(self, params: dict) -> None:
        import jax

        def alloc(a):
            z = jnp.zeros((self.capacity,) + a.shape, a.dtype)
            return z

        self._bank = jax.tree.map(alloc, dict(params))
        if self.mesh is not None:
            from repro.sharding.rules import head_bank_shardings
            shardings = head_bank_shardings(self._bank, self.mesh)
            self._bank = {k: jax.device_put(v, shardings[k])
                          for k, v in self._bank.items()}

    def _write_slot(self, slot: int, params: dict) -> None:
        for k, v in params.items():
            if k not in self._bank:
                raise ValueError(
                    f"tenant head has unexpected leaf {k!r}; bank leaves "
                    f"are {sorted(self._bank)} — all tenants must share "
                    f"one quantization mode and config")
            self._bank[k] = self._bank[k].at[slot].set(
                jnp.asarray(v, self._bank[k].dtype))
        missing = set(self._bank) - set(params)
        if missing:
            raise ValueError(
                f"tenant head is missing leaves {sorted(missing)}; all "
                f"tenants must share one quantization mode and config")

    def _touch(self, tenant) -> None:
        if tenant in self._lru:
            self._lru.remove(tenant)
        self._lru.append(tenant)

    def _free_slot(self) -> int:
        used = set(self._slot_of.values())
        for s in range(self.capacity):
            if s not in used:
                return s
        # Evict the least-recently-used unpinned tenant.
        for victim in self._lru:
            if self._refs.get(victim, 0) == 0:
                slot = self._slot_of.pop(victim)
                self._lru.remove(victim)
                self._refs.pop(victim, None)
                self.stats["evictions"] += 1
                return slot
        raise RuntimeError(
            f"HeadCache: all {self.capacity} resident tenants are pinned "
            f"by live slots; raise capacity or drain requests")

    # -- public ------------------------------------------------------------

    def acquire(self, tenant) -> int:
        """Pin ``tenant`` resident (paging it in on miss); returns its slot.

        Each ``acquire`` must be balanced by one :meth:`release` when the
        tenant's last live engine slot retires.
        """
        if tenant in self._slot_of:
            self.stats["hits"] += 1
            self._refs[tenant] = self._refs.get(tenant, 0) + 1
            self._touch(tenant)
            return self._slot_of[tenant]
        self.stats["misses"] += 1
        params = self._loader(tenant)
        self.stats["loads"] += 1
        if self._bank is None:
            self._init_bank(params)
        slot = self._free_slot()
        self._write_slot(slot, params)
        self._slot_of[tenant] = slot
        self._refs[tenant] = self._refs.get(tenant, 0) + 1
        self._touch(tenant)
        return slot

    def release(self, tenant) -> None:
        """Unpin one reference; the tenant stays resident until evicted."""
        refs = self._refs.get(tenant, 0)
        if refs <= 0:
            raise ValueError(f"release of tenant {tenant!r} with no "
                             f"outstanding acquire")
        self._refs[tenant] = refs - 1

    def slot(self, tenant) -> int:
        """The resident bank slot of ``tenant`` (KeyError if paged out)."""
        return self._slot_of[tenant]

    def resident(self) -> list:
        """Resident tenants in LRU→MRU order."""
        return list(self._lru)

    def tenant_params(self, tenant) -> dict:
        """The resident tenant's params, sliced back out of the bank."""
        slot = self._slot_of[tenant]
        return {k: v[slot] for k, v in self._bank.items()}

    def publish(self, tenant, params: dict) -> None:
        """Overwrite a resident tenant's bank row — the refresh commit.

        In-flight dispatches keep reading the old bank arrays (JAX arrays
        are immutable; ``.at[].set`` builds new ones), so a publish between
        engine ticks never exposes a half-updated head.
        """
        if tenant not in self._slot_of:
            raise KeyError(f"tenant {tenant!r} is not resident; acquire it "
                           f"before publishing a refresh")
        self._write_slot(self._slot_of[tenant], params)
        self._touch(tenant)

    def bank_params(self, tenant_ids) -> dict:
        """The decode-ready param dict: stacked bank + per-slot tenant ids.

        Args:
          tenant_ids: (B,) int array of *bank slots* (``self.slot(t)`` per
            engine slot; free engine slots may carry any valid index).

        Returns:
          ``dict(**bank, tenant_ids=int32 array)`` — exactly what a
          ``per_tenant`` :class:`SketchHead` expects as runtime params.
        """
        if self._bank is None:
            raise RuntimeError("HeadCache is empty; acquire a tenant first")
        out = dict(self._bank)
        out["tenant_ids"] = jnp.asarray(tenant_ids, jnp.int32)
        return out
