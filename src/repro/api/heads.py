"""First-class logit heads: the ``LogitHead`` registry (DESIGN.md §8).

The paper's pitch is that a Representer Sketch is a *drop-in replacement*
for the dense inference path.  This module makes the swap an object, not a
flag: a ``LogitHead`` is a hashable spec of how decode-time logits are
produced — its *kind* (``dense`` / ``sketch``), its kernel *backend*
(``fused`` / ``two_kernel`` / ``ref``), and, for heads with state, the
frozen arrays.  Head specs key the jitted-step memo cache
(``launch.steps.jitted_serve_fns``); the arrays ride along as a runtime
argument so two heads that compile identically share one executable.

Adding a third head kind is one ``@register_head`` class — no call-site
edits in launch/, examples/, or benchmarks/.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Type

import jax.numpy as jnp

from repro.core.sketch_lm_head import HEAD_BACKENDS as SKETCH_BACKENDS
from repro.models.config import SketchHeadConfig

HEAD_KINDS: Dict[str, Type["LogitHead"]] = {}


def register_head(kind: str):
    """Class decorator: register a LogitHead subclass under ``kind``."""

    def deco(cls):
        HEAD_KINDS[kind] = cls
        return cls

    return deco


def get_head_class(kind: str) -> Type["LogitHead"]:
    if kind not in HEAD_KINDS:
        raise KeyError(
            f"unknown head kind {kind!r}; registered: {sorted(HEAD_KINDS)}")
    return HEAD_KINDS[kind]


@dataclasses.dataclass(frozen=True)
class LogitHead:
    """Base spec: hashable, equality on static config only.

    ``needs_hidden`` tells ``serve_step`` whether the backbone should return
    the final hidden (head produces logits) or run its own dense unembed.
    ``params`` (on stateful heads) is excluded from hash/eq so the spec can
    key jit memo caches; always pass ``head.params`` as a runtime argument.
    """

    kind = "abstract"
    needs_hidden = False
    params = None  # stateless by default

    def apply(self, params: Any, hidden: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def without_params(self) -> "LogitHead":
        """The bare spec — what jit memo caches should key on."""
        return self

    def with_params(self, params: Any) -> "LogitHead":
        if params is not None:
            raise ValueError(f"{type(self).__name__} is stateless")
        return self

    def describe(self) -> str:
        return self.kind


@register_head("dense")
@dataclasses.dataclass(frozen=True)
class DenseHead(LogitHead):
    """The backbone's own ``h · Wᵀ`` unembed — logits come straight out of
    ``decode_step``; this head carries no state and applies nothing."""

    kind = "dense"
    needs_hidden = False

    def apply(self, params, hidden):
        raise RuntimeError(
            "DenseHead logits come from the backbone's unembed; "
            "serve_step should not call apply()")


@register_head("sketch")
@dataclasses.dataclass(frozen=True)
class SketchHead(LogitHead):
    """The Representer-Sketch head: frozen (proj, w, b, array) params plus a
    decode backend.

    ``backend``:
      * ``"fused"``      — one pallas_call: transform → hash → gather
                           (repro.kernels.fused_decode; the serving default),
      * ``"two_kernel"`` — lsh_hash → sketch_head composition (the unfused
                           baseline, (B, L) indices round-trip through HBM),
      * ``"ref"``        — the pure-jnp oracle composition (CPU/CI parity).

    The kernel-level pallas/ref choice *within* ``fused``/``two_kernel`` is
    the kernel registry's (``REPRO_KERNEL_BACKEND``, DESIGN.md §8).
    """

    kind = "sketch"
    needs_hidden = True

    cfg: SketchHeadConfig = dataclasses.field(default_factory=SketchHeadConfig)
    backend: str = "fused"
    params: Optional[dict] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.backend not in SKETCH_BACKENDS:
            raise ValueError(
                f"unknown sketch-head backend {self.backend!r}; "
                f"expected one of {SKETCH_BACKENDS}")

    def apply(self, params: dict, hidden: jnp.ndarray) -> jnp.ndarray:
        from repro.core.sketch_lm_head import apply_head
        if params is None:
            raise ValueError(
                "SketchHead.apply needs the frozen head params; build them "
                "with freeze_head/distill_head or load them with "
                "SketchHead.load")
        return apply_head(params, hidden, self.cfg, backend=self.backend)

    def without_params(self) -> "SketchHead":
        if self.params is None:
            return self
        return dataclasses.replace(self, params=None)

    def with_params(self, params: dict) -> "SketchHead":
        return dataclasses.replace(self, params=params)

    def with_backend(self, backend: str) -> "SketchHead":
        return dataclasses.replace(self, backend=backend)

    def describe(self) -> str:
        return f"sketch/{self.backend}"

    # -- persistence (round-trips kind + backend, DESIGN.md §8) ------------

    def save(self, path) -> None:
        from repro.core.sketch_lm_head import save_head
        if self.params is None:
            raise ValueError("cannot save a SketchHead without params")
        save_head(path, self.params, self.cfg,
                  kind=self.kind, backend=self.backend)

    @classmethod
    def load(cls, path) -> "SketchHead":
        from repro.core.sketch_lm_head import load_head_full
        params, cfg, meta = load_head_full(path)
        return cls(cfg=cfg, backend=meta["backend"], params=params)


def load_head(path) -> LogitHead:
    """Load any saved head; dispatches on the stored ``kind`` metadata."""
    from repro.core.sketch_lm_head import load_head_meta
    kind = load_head_meta(path)["kind"]
    cls = get_head_class(kind)
    if not hasattr(cls, "load"):
        raise TypeError(f"head kind {kind!r} does not support load()")
    return cls.load(path)
