"""The ``LM`` facade: one request-level entry point for the serving surface.

``LM`` binds (params, config, head, mesh) once; ``generate()`` routes to the
static batch path and ``serve()`` to the continuous-batching engine, both
through the same ``LogitHead`` / ``Sampler`` objects — "sketch in, sketch
out": swapping the dense head for a Representer Sketch (or a new registered
head kind, or a different kernel backend) is a constructor argument, not a
flag threaded through eight call sites.  A ``mesh`` makes every path
SPMD-sharded end-to-end (DESIGN.md §9): params placed by
``sharding/rules.py``, decode caches batch-sharded over ``data``, sketch
count arrays partitioned over ``model`` with one psum per decode step.

    from repro.api import LM, Sampler, SketchHead

    lm = LM.from_config("rwkv6-1.6b", smoke=True)
    tokens = lm.generate(prompts, max_new_tokens=16)

    lm = lm.with_head(SketchHead.load("head.npz"))
    finished = lm.serve([(prompt, 16) for prompt in prompts], n_slots=4,
                        sampler=Sampler(temperature=0.8, top_p=0.9, seed=1))

    sharded = LM.from_config("rwkv6-1.6b", smoke=True, mesh="4x2",
                             head=SketchHead.load("head.npz"))
    tokens = sharded.generate(prompts, max_new_tokens=16)  # same streams
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.heads import DenseHead, LogitHead
from repro.api.sampler import Sampler
from repro.models.config import ModelConfig

#: A serve request: (prompt, max_new_tokens) or (prompt, max_new_tokens, arrival).
RequestLike = Union[Tuple[Any, int], Tuple[Any, int, int]]


def _place(params, head: LogitHead, mesh):
    """Shard model params (and any head params) onto ``mesh``."""
    from repro.launch.mesh import place_serving_state

    return place_serving_state(params, head, mesh)


@dataclasses.dataclass
class LM:
    """A servable model: backbone params + config + a first-class head.

    Attributes:
      params: the backbone parameter pytree.
      cfg: the architecture's ``ModelConfig``.
      head: the ``LogitHead`` producing decode-time logits (dense default).
      mesh: optional ``jax.sharding.Mesh`` — when set, serving runs SPMD
        over it (construct via :meth:`from_config` / :meth:`with_mesh` so
        params are placed; a hand-built instance is not auto-placed).
    """

    params: Any
    cfg: ModelConfig
    head: LogitHead = dataclasses.field(default_factory=DenseHead)
    mesh: Any = None

    @classmethod
    def from_config(cls, arch: str, *, smoke: bool = False,
                    head: Optional[LogitHead] = None, params: Any = None,
                    mesh=None, seed: int = 0) -> "LM":
        """Build an LM from a registered arch config.

        Args:
          arch: a registered architecture name (``repro.configs``).
          smoke: use the arch's CPU-scale smoke variant.
          head: the serving ``LogitHead`` (dense unembed if omitted).
          params: backbone params to serve (random init per ``seed`` if
            omitted).
          mesh: serving mesh — a ``jax.sharding.Mesh`` or a ``"<data>x
            <model>"`` spec string (e.g. ``"4x2"``); params and head arrays
            are placed per ``sharding/rules.py``.
          seed: PRNG seed for the random init.

        Returns:
          A ready-to-serve ``LM``.

        Raises:
          KeyError: unknown ``arch``.
          ValueError: malformed mesh spec or not enough devices.
        """
        from repro.configs import get_config
        from repro.launch.mesh import parse_mesh
        from repro.models.model import init_model

        cfg = get_config(arch, smoke=smoke)
        if params is None:
            params = init_model(jax.random.PRNGKey(seed), cfg)
        head = head or DenseHead()
        mesh = parse_mesh(mesh)
        if mesh is not None:
            params, head = _place(params, head, mesh)
        return cls(params, cfg, head, mesh)

    def with_head(self, head: LogitHead) -> "LM":
        """The same model serving through a different head.

        Args:
          head: the new ``LogitHead``; its arrays are placed on this LM's
            mesh (if any).

        Returns:
          A new ``LM`` sharing params/cfg/mesh.
        """
        if self.mesh is not None and head.params is not None:
            from repro.launch.mesh import place_serving_state
            _, head = place_serving_state(self.params, head, self.mesh)
        return dataclasses.replace(self, head=head)

    def with_mesh(self, mesh) -> "LM":
        """This model re-placed onto a serving mesh (or off of one).

        Args:
          mesh: ``None`` (single-device), a ``jax.sharding.Mesh``, or a
            ``"<data>x<model>"`` spec string.

        Returns:
          A new ``LM`` with params and head arrays placed on the mesh.
        """
        from repro.launch.mesh import parse_mesh

        mesh = parse_mesh(mesh)
        params, head = self.params, self.head
        if mesh is not None:
            params, head = _place(params, head, mesh)
        elif self.mesh is not None:
            # Un-shard: gather back to one device so single-device serve fns
            # don't mix committed multi-device and fresh single-device arrays.
            dev = jax.devices()[0]
            params = jax.device_put(params, dev)
            if head.params is not None:
                head = head.with_params(jax.device_put(head.params, dev))
        return dataclasses.replace(self, params=params, head=head, mesh=mesh)

    # -- static batch --------------------------------------------------------

    def generate(self, prompts, max_new_tokens: int, *,
                 sampler: Optional[Sampler] = None,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 encoder_states=None, decode_chunk: int = 1,
                 spec_decode: int = 0,
                 return_stats: bool = False) -> jnp.ndarray:
        """Bulk prefill + decode one (B, P) batch → (B, P + max_new_tokens).

        Args:
          prompts: (B, P) (or (P,)) int32 prompt token ids.
          max_new_tokens: tokens to decode per sequence.
          sampler: token-selection policy (greedy if omitted).
          eos_id: with it, sequences that emit it stop — later positions
            hold ``pad_id`` and the decode loop exits once every row is done
            (parity with the engine's per-request retirement).
          pad_id: filler token for stopped rows.
          encoder_states: (B, T_enc, d) states for encoder-conditioned archs.
          decode_chunk: tokens decoded per device dispatch — ``K > 1`` runs
            the on-device ``lax.scan`` megastep with sampling and EOS
            retirement fused in (launch/decode_loop.py, DESIGN.md §10);
            1 (default) is the per-token host loop, bitwise reference.
          spec_decode: speculative self-decode draft length — ``K > 0``
            drafts K tokens per dispatch through this LM's ``head`` and
            verifies the block with one batched dense pass (DESIGN.md
            §11); the emitted stream is bitwise the dense stream.
            Mutually exclusive with ``decode_chunk > 1``.
          return_stats: also return the decode stats dict (with
            ``spec_decode``: ``verify_calls`` / ``draft_tokens`` /
            ``accepted_draft_tokens``).

        Returns:
          (B, P + max_new_tokens) int32 tokens (prompt included); with
          ``return_stats``, a ``(tokens, stats)`` pair.
        """
        from repro.launch.serve import generate

        prompts = jnp.asarray(prompts, jnp.int32)
        if prompts.ndim == 1:
            prompts = prompts[None]
        return generate(self.params, self.cfg, prompts, max_new_tokens,
                        encoder_states=encoder_states, head=self.head,
                        sampler=sampler, eos_id=eos_id, pad_id=pad_id,
                        mesh=self.mesh, decode_chunk=decode_chunk,
                        spec_decode=spec_decode, return_stats=return_stats)

    # -- continuous batching -------------------------------------------------

    def engine(self, n_slots: int, max_seq: int, *,
               sampler: Optional[Sampler] = None,
               eos_id: Optional[int] = None, decode_chunk: int = 1,
               spec_decode: int = 0, paged: bool = False,
               page_size: int = 16, num_pages: Optional[int] = None,
               head_cache=None):
        """A fresh continuous-batching ServeEngine over this (model, head).

        Args:
          n_slots: decode-cache slot-pool size.
          max_seq: per-slot cache length (prompt + generation budget).
          sampler: token-selection policy (greedy if omitted).
          eos_id: optional early-retirement token.
          decode_chunk: tokens decoded per occupied slot between admission
            rounds — ``K > 1`` runs one on-device megastep per tick
            (DESIGN.md §10); 1 (default) keeps the bitwise-parity
            per-token tick.
          spec_decode: speculative self-decode draft length — every tick
            drafts K tokens through this LM's ``head`` and dense-verifies
            them (DESIGN.md §11); mutually exclusive with
            ``decode_chunk > 1``.
          paged: allocate the attention/MLA decode caches as a shared page
            pool with per-slot page tables and an exact-prompt prefix cache
            (DESIGN.md §13) instead of contiguous per-slot rows.  Bitwise
            identical outputs; repeated prompts prefill once.  Mutually
            exclusive with ``decode_chunk > 1`` and ``spec_decode``.
          page_size: tokens per page along the sequence axis (paged only).
          num_pages: page-pool capacity override (paged only; sized from
            ``n_slots``/``max_seq`` when omitted).
          head_cache: a ``repro.api.HeadCache`` for per-tenant serving
            (DESIGN.md §14): this LM's head becomes the shared sketch spec
            while each slot decodes through its request's tenant's arrays;
            every ``submit`` then needs ``tenant=``.  Mutually exclusive
            with ``spec_decode``.

        Returns:
          A ``repro.launch.engine.ServeEngine`` (mesh-aware when this LM
          has a mesh).
        """
        from repro.launch.engine import make_engine

        return make_engine(self.params, self.cfg, n_slots=n_slots,
                           max_seq=max_seq, head=self.head,
                           sampler=sampler, eos_id=eos_id, mesh=self.mesh,
                           decode_chunk=decode_chunk,
                           spec_decode=spec_decode, paged=paged,
                           page_size=page_size, num_pages=num_pages,
                           head_cache=head_cache)

    def serve(self, requests: Iterable[RequestLike], *, n_slots: int = 4,
              max_seq: Optional[int] = None,
              sampler: Optional[Sampler] = None,
              eos_id: Optional[int] = None, decode_chunk: int = 1,
              spec_decode: int = 0, paged: bool = False,
              page_size: int = 16) -> Dict[int, List[int]]:
        """Serve a request stream through the engine.

        Args:
          requests: iterables of ``(prompt, max_new_tokens[, arrival])``.
          n_slots: engine slot-pool size.
          max_seq: per-slot cache length (inferred from the longest request
            if omitted).
          sampler: token-selection policy (greedy if omitted).
          eos_id: optional early-retirement token.
          decode_chunk: engine megastep size (see :meth:`engine`).
          spec_decode: speculative draft length (see :meth:`engine`).
          paged: paged cache pool + prefix cache (see :meth:`engine`).
          page_size: tokens per page when ``paged`` (see :meth:`engine`).

        Returns:
          Per request id (submission order), the generated tokens (prompt
          excluded).
        """
        reqs: List[Tuple[np.ndarray, int, int]] = []
        for r in requests:
            prompt, max_new = np.asarray(r[0], np.int32).reshape(-1), int(r[1])
            arrival = int(r[2]) if len(r) > 2 else 0
            reqs.append((prompt, max_new, arrival))
        if not reqs:
            return {}
        if max_seq is None:
            max_seq = max(len(p) + g for p, g, _ in reqs)
        engine = self.engine(n_slots, max_seq, sampler=sampler, eos_id=eos_id,
                             decode_chunk=decode_chunk,
                             spec_decode=spec_decode, paged=paged,
                             page_size=page_size)
        for prompt, max_new, arrival in reqs:
            engine.submit(prompt, max_new, arrival=arrival)
        return engine.run()
