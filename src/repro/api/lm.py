"""The ``LM`` facade: one request-level entry point for the serving surface.

``LM`` binds (params, config, head) once; ``generate()`` routes to the
static batch path and ``serve()`` to the continuous-batching engine, both
through the same ``LogitHead`` / ``Sampler`` objects — "sketch in, sketch
out": swapping the dense head for a Representer Sketch (or a new registered
head kind, or a different kernel backend) is a constructor argument, not a
flag threaded through eight call sites.

    from repro.api import LM, Sampler, SketchHead

    lm = LM.from_config("rwkv6-1.6b", smoke=True)
    tokens = lm.generate(prompts, max_new_tokens=16)

    lm = lm.with_head(SketchHead.load("head.npz"))
    finished = lm.serve([(prompt, 16) for prompt in prompts], n_slots=4,
                        sampler=Sampler(temperature=0.8, top_p=0.9, seed=1))
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.heads import DenseHead, LogitHead
from repro.api.sampler import Sampler
from repro.models.config import ModelConfig

#: A serve request: (prompt, max_new_tokens) or (prompt, max_new_tokens, arrival).
RequestLike = Union[Tuple[Any, int], Tuple[Any, int, int]]


@dataclasses.dataclass
class LM:
    """A servable model: backbone params + config + a first-class head."""

    params: Any
    cfg: ModelConfig
    head: LogitHead = dataclasses.field(default_factory=DenseHead)

    @classmethod
    def from_config(cls, arch: str, *, smoke: bool = False,
                    head: Optional[LogitHead] = None, params: Any = None,
                    seed: int = 0) -> "LM":
        """Build an LM from a registered arch config (random init unless
        ``params`` is given)."""
        from repro.configs import get_config
        from repro.models.model import init_model

        cfg = get_config(arch, smoke=smoke)
        if params is None:
            params = init_model(jax.random.PRNGKey(seed), cfg)
        return cls(params, cfg, head or DenseHead())

    def with_head(self, head: LogitHead) -> "LM":
        """The same model serving through a different head."""
        return dataclasses.replace(self, head=head)

    # -- static batch --------------------------------------------------------

    def generate(self, prompts, max_new_tokens: int, *,
                 sampler: Optional[Sampler] = None,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 encoder_states=None) -> jnp.ndarray:
        """Bulk prefill + decode one (B, P) batch → (B, P + max_new_tokens).

        With ``eos_id``, sequences that emit it stop: later positions hold
        ``pad_id`` and the decode loop exits once every row is done (parity
        with the engine's per-request retirement).
        """
        from repro.launch.serve import generate

        prompts = jnp.asarray(prompts, jnp.int32)
        if prompts.ndim == 1:
            prompts = prompts[None]
        return generate(self.params, self.cfg, prompts, max_new_tokens,
                        encoder_states=encoder_states, head=self.head,
                        sampler=sampler, eos_id=eos_id, pad_id=pad_id)

    # -- continuous batching -------------------------------------------------

    def engine(self, n_slots: int, max_seq: int, *,
               sampler: Optional[Sampler] = None,
               eos_id: Optional[int] = None):
        """A fresh continuous-batching ServeEngine over this (model, head)."""
        from repro.launch.engine import make_engine

        return make_engine(self.params, self.cfg, n_slots=n_slots,
                           max_seq=max_seq, head=self.head,
                           sampler=sampler, eos_id=eos_id)

    def serve(self, requests: Iterable[RequestLike], *, n_slots: int = 4,
              max_seq: Optional[int] = None,
              sampler: Optional[Sampler] = None,
              eos_id: Optional[int] = None) -> Dict[int, List[int]]:
        """Serve a request stream through the engine; returns, per request id
        (submission order), the generated tokens (prompt excluded)."""
        reqs: List[Tuple[np.ndarray, int, int]] = []
        for r in requests:
            prompt, max_new = np.asarray(r[0], np.int32).reshape(-1), int(r[1])
            arrival = int(r[2]) if len(r) > 2 else 0
            reqs.append((prompt, max_new, arrival))
        if not reqs:
            return {}
        if max_seq is None:
            max_seq = max(len(p) + g for p, g, _ in reqs)
        engine = self.engine(n_slots, max_seq, sampler=sampler, eos_id=eos_id)
        for prompt, max_new, arrival in reqs:
            engine.submit(prompt, max_new, arrival=arrival)
        return engine.run()
