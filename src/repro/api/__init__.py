"""``repro.api`` — the one public serving surface (DESIGN.md §8).

Everything a user needs to run the paper's pipeline or serve a model sits
behind this facade:

* **Heads** — ``DenseHead`` / ``SketchHead`` specs with a ``backend``
  (``fused`` / ``two_kernel`` / ``ref``) replacing the old ``fused: bool``
  plumbing; ``register_head`` adds new kinds; ``load_head`` round-trips
  kind + backend from disk.
* **Sampling** — ``Sampler`` (greedy / temperature / top-k / top-p, seeded
  key chain) replacing the ``greedy: bool`` + ``seed`` pair.
* **Serving** — ``LM.from_config(...).generate(...)`` / ``.serve(requests)``
  routing to the static batch path or the continuous-batching engine;
  ``mesh="4x2"`` serves SPMD over a ``(data, model)`` device mesh with the
  sketch count arrays partitioned over ``model`` (DESIGN.md §9).
* **Kernels** — ``kernel_backends`` (the registry): per-call ``backend=`` or
  global ``REPRO_KERNEL_BACKEND`` dispatch between pallas and ref.
* **Paper core** — the RACE sketch objects, re-exported from ``repro.core``.
"""

from repro.api.heads import (HEAD_KINDS, SKETCH_BACKENDS, DenseHead,
                             HeadCache, LogitHead, SketchHead,
                             get_head_class, load_head, register_head)
from repro.api.lm import LM
from repro.api.sampler import Sampler
from repro.core import RepresenterSketch, SketchConfig
from repro.kernels import registry as kernel_backends
from repro.models.config import SketchHeadConfig

__all__ = [
    "LM",
    "Sampler",
    "LogitHead",
    "DenseHead",
    "SketchHead",
    "SketchHeadConfig",
    "HEAD_KINDS",
    "HeadCache",
    "SKETCH_BACKENDS",
    "register_head",
    "get_head_class",
    "load_head",
    "kernel_backends",
    "RepresenterSketch",
    "SketchConfig",
]
