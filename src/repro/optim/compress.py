"""Int8 gradient compression with error feedback (distributed-opt trick).

For bandwidth-bound DP all-reduces: each replica quantizes its local
gradient to int8 with a per-tensor scale, the all-reduce (``jax.lax.psum``
inside ``shard_map``) runs on the int8 payload (~4× less ICI traffic), and
the quantization residual is carried in an *error-feedback* buffer added to
the next step's gradient — the EF-SGD construction that keeps convergence
unbiased in the limit.

Used by launch/train.py when ``grad_compress=True``; validated for
correctness-in-expectation in tests/test_optim.py.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grad_leaf(g: jnp.ndarray, err: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize (g + error feedback); return (q, scale, new_error)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(tree, err_tree, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` for every leaf.

    Must run inside shard_map with ``axis_name`` bound.  Scales are psum'd
    in f32 (negligible bytes); payloads as int32 accumulations of int8
    values (jax has no int8 collectives on all backends, so we cast the
    int8 payload to int32 — on TPU the MARSHALLED bytes are what matter and
    XLA packs small integers; the 4× saving claim is validated structurally
    in tests by byte accounting, see tests/test_optim.py).
    Returns (mean_tree, new_err_tree).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        # Agree on a shared scale (one scalar pmax — negligible traffic),
        # then quantize once against it so the int8 sum dequantizes exactly.
        local_scale = jnp.max(jnp.abs(target)) / 127.0 + 1e-12
        smax = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(target / smax), -127, 127).astype(jnp.int8)
        new_e = target - q.astype(jnp.float32) * smax
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * smax / n
        return mean, new_e

    pairs = jax.tree.map(one, tree, err_tree)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
