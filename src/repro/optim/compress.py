"""Symmetric integer quantization helpers + int8 gradient compression.

Two consumers share the symmetric-scale construction:

* **Gradient compression** (``compressed_psum``): per-tensor int8 scales for
  bandwidth-bound DP all-reduces, with an error-feedback buffer carrying the
  residual into the next step (EF-SGD; used by launch/train.py when
  ``grad_compress=True``, validated in tests/test_optim.py).
* **Quantized sketch-head storage** (``core.sketch_lm_head.quantize_head``):
  per-*row* int8/int4 scales over the (L, R, V) count arrays — the paper's
  storage-reduction claim (DESIGN.md §12).  ``quantize_symmetric`` is the
  shared generalization: reduce |x| over ``axis`` instead of the whole
  tensor, guard all-zero rows so no scale is 0/inf/nan.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp


def quantize_symmetric(
    x: jnp.ndarray,
    *,
    bits: int = 8,
    axis: Optional[Union[int, Tuple[int, ...]]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric signed quantization with per-``axis``-slice scales.

    Args:
      x: the float array to quantize.
      bits: target signed bit width (8 → values in [-127, 127]; 4 → values
        in [-7, 7], stored in an int8 carrier — pack with
        ``kernels.common.pack_int4_rows`` for 2×/byte storage).
      axis: the reduction axis/axes of the amax. ``None`` gives one
        per-tensor scale (a 0-d array); an axis gives one scale per
        remaining slice ("per-row": for an (L, R, V) count array,
        ``axis=-1`` yields (L, R) scales, one per gathered V-row).

    Returns:
      ``(q, scale)`` — ``q`` int8 with values in [-qmax, qmax], ``scale``
      f32 with the ``axis`` dims squeezed out, such that ``q * scale ≈ x``.
      All-zero (and hence constant-zero) slices get scale 1.0, not 0: the
      guard keeps both ``x / scale`` here and any downstream
      ``1 / scale`` finite (no inf/nan rows — tests/test_quant.py).
    """
    qmax = float(2 ** (bits - 1) - 1)
    ax = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(ax), axis=axis, keepdims=axis is not None)
    scale = jnp.where(amax > 0, amax, 1.0) / qmax
    q = jnp.clip(jnp.round(ax / scale), -qmax, qmax).astype(jnp.int8)
    if axis is not None:
        scale = jnp.squeeze(scale, axis)
    return q, scale.astype(jnp.float32)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grad_leaf(g: jnp.ndarray, err: jnp.ndarray,
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize (g + error feedback); return (q, scale, new_error)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(tree, err_tree, axis_name: str):
    """Error-feedback int8 psum over ``axis_name`` for every leaf.

    Must run inside shard_map with ``axis_name`` bound.  Scales are psum'd
    in f32 (negligible bytes); payloads as int32 accumulations of int8
    values (jax has no int8 collectives on all backends, so we cast the
    int8 payload to int32 — on TPU the MARSHALLED bytes are what matter and
    XLA packs small integers; the 4× saving claim is validated structurally
    in tests by byte accounting, see tests/test_optim.py).
    Returns (mean_tree, new_err_tree).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        # Agree on a shared scale (one scalar pmax — negligible traffic),
        # then quantize once against it so the int8 sum dequantizes exactly.
        local_scale = jnp.max(jnp.abs(target)) / 127.0 + 1e-12
        smax = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(target / smax), -127, 127).astype(jnp.int8)
        new_e = target - q.astype(jnp.float32) * smax
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * smax / n
        return mean, new_e

    pairs = jax.tree.map(one, tree, err_tree)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
