"""Sharded AdamW with cosine schedule, clipping and f32 master weights.

Optimizer state is a pytree congruent with the parameters, so it inherits
the parameter PartitionSpecs; with ``zero1`` (sharding/rules.py) the m/v/
master leaves are additionally sharded over the data axes — ZeRO-1 without
any gather/scatter code because pjit materializes each leaf only where the
spec places it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # Memory-lean mode: bf16 moments + no f32 master (6 B/param total state
    # instead of 14).  Required to fit 671B-class training on 16 GB chips at
    # pod scale (EXPERIMENTS.md §Perf iter 5); costs some update precision.
    lean: bool = False
    # Microbatches per step (gradient accumulation): divides activation
    # transients by grad_accum at the cost of re-running the fwd/bwd scan.
    grad_accum: int = 1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any  # f32 master copy of bf16 params


def init_adamw(params, lean: bool = False) -> AdamWState:
    mdt = jnp.bfloat16 if lean else jnp.float32
    mom = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(mom, params),
        nu=jax.tree.map(mom, params),
        # copy=True: f32 params would otherwise alias the master buffer and
        # break double-donation checks in the jitted step.  Lean mode keeps
        # no master — params are updated in their own dtype.
        master=(None if lean else jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)),
    )


def lr_schedule(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_update(
    grads, state: AdamWState, cfg: OptimizerConfig, params=None,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new bf16 params, new state, metrics).

    ``params`` is required in lean mode (no master copy in the state).
    """
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(step, cfg)
    mdt = jnp.bfloat16 if cfg.lean else jnp.float32

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / (1 - cfg.b1 ** step)
        vhat = v32 / (1 - cfg.b2 ** step)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return m32.astype(mdt), v32.astype(mdt), p32

    ref = state.master if state.master is not None else params
    assert ref is not None, "lean mode needs params passed to adamw_update"
    flat = jax.tree.map(upd, grads, state.mu, state.nu, ref)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=is_triple)
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=is_triple)
    master = jax.tree.map(lambda t: t[2], flat, is_leaf=is_triple)
    # Cotangents carry the original parameter dtypes (bf16 params → bf16
    # grads), so grads — not the f32 master — are the dtype reference.
    new_params = jax.tree.map(lambda g, m: m.astype(jnp.bfloat16)
                              if g.dtype == jnp.bfloat16 else m,
                              grads, master)
    new_master = None if cfg.lean else master
    return new_params, AdamWState(step, mu, nu, new_master), {
        "grad_norm": gnorm, "lr": lr}
