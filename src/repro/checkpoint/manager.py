"""Checkpointing: per-host shard files, async writer, manifest, restart.

Production layout (one directory per step)::

    ckpt_dir/
      step_000100/
        shard_00000.npz        # this host's param/opt leaves (flattened)
        ...
        MANIFEST.json          # written LAST — marks the step complete

Crash-safety: the manifest is written only after every shard file is
fsync'd, so a step directory without a manifest is garbage and
``latest_step`` skips it (tests kill a writer mid-flight and assert restart
falls back to the previous complete step).  Saving is asynchronous — the
train loop hands off host-local numpy copies and continues; ``wait()``
drains the writer (called before exit and before deleting old steps).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # npz can't round-trip ml_dtypes; store the raw uint16 bits
            # (restore() bitcasts back using the template's dtype).
            arr = arr.view(np.uint16)
        out.append((key, arr))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, host_id: int = 0, n_hosts: int = 1,
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.keep = keep
        self._pending: List[threading.Thread] = []
        self._errors: List[BaseException] = []
        self._lock = threading.Lock()

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot ``tree`` (host-local views) for ``step``; async by default."""
        items = _flatten_with_paths(tree)  # copies to host numpy

        def worker():
            try:
                self._write(step, items)
            except BaseException as e:  # surfaced on wait()
                with self._lock:
                    self._errors.append(e)

        t = threading.Thread(target=worker, daemon=True)
        with self._lock:
            self._pending.append(t)
        t.start()
        if blocking:
            t.join()
            self._raise_errors()

    def _raise_errors(self) -> None:
        with self._lock:
            errors, self._errors = self._errors, []
        if errors:
            raise errors[0]

    def _write(self, step: int, items) -> None:
        step_dir = self.dir / f"step_{step:09d}"
        step_dir.mkdir(parents=True, exist_ok=True)
        shard = step_dir / f"shard_{self.host_id:05d}.npz"
        tmp = shard.with_suffix(".tmp")
        with open(tmp, "wb") as f:      # file handle: np.savez can't rename
            np.savez(f, **{k: v for k, v in items})
        os.replace(tmp, shard)          # atomic rename
        with open(shard, "rb") as f:    # ensure durability before manifest
            os.fsync(f.fileno())
        if self.host_id == 0:
            # In multi-host deployment host 0 would barrier on all shards;
            # here n_hosts==1 in-process, so write the manifest directly.
            manifest = step_dir / "MANIFEST.json"
            mtmp = manifest.with_suffix(".tmp")
            mtmp.write_text(json.dumps({
                "step": step,
                "n_hosts": self.n_hosts,
                "time": time.time(),
                "keys": [k for k, _ in items],
            }))
            os.replace(mtmp, manifest)
        self._gc()

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()
        self._raise_errors()

    def _gc(self) -> None:
        steps = self.complete_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def complete_steps(self) -> List[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "MANIFEST.json").exists():
                out.append(int(d.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure of ``template``. Returns (tree, step)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        shard = self.dir / f"step_{step:09d}" / f"shard_{self.host_id:05d}.npz"
        data = np.load(shard)
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in paths:
            key = jax.tree_util.keystr(path)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            if (leaf.dtype == jax.numpy.bfloat16
                    and arr.dtype.itemsize == 2 and arr.dtype.kind in "uV"):
                # bitcast the stored uint16 payload back to bf16
                arr = jax.numpy.asarray(arr.view(np.uint16)).view(
                    jax.numpy.bfloat16)
                leaves.append(arr)
            else:
                # Cast via jax: numpy lacks cast kernels for ml_dtypes.
                leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
