"""gemma2-27b — dense GQA, local/global alternating + softcaps [arXiv:2408.00118].

46L, d_model=4608, 32 heads (GQA kv=16, head_dim=128), d_ff=36864,
vocab=256000.  Sliding window 4096 on local layers; attention softcap 50,
final-logit softcap 30.  Global layers are full attention → long_500k skipped.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab_size=256000,
    pattern=("attn_local", "attn_global"),
    attention=AttentionConfig(n_heads=32, n_kv_heads=16, head_dim=128,
                              window=4096, logit_softcap=50.0,
                              rope_theta=10000.0),
    final_logit_softcap=30.0,
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="gemma2-27b-smoke", n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=8,
                              logit_softcap=50.0),
)
