"""deepseek-v3-671b — MLA + 256-expert MoE (1 shared, top-8) [arXiv:2412.19437].

61L, d_model=7168, 128 heads MLA (q_lora=1536, kv_lora=512, nope=128,
rope=64, v=128), 3 dense prologue layers (d_ff=18432) then MoE with expert
d_ff=2048, vocab=129280.  The MLA latent cache (512+64 per position) is a
57× KV compression → long_500k RUNS on the latent cache (DESIGN.md §5).
MTP (multi-token prediction) is a training-objective add-on the backbone
does not require; noted as out of scope in DESIGN.md.
"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    d_ff=18432,            # dense prologue FFN width
    vocab_size=129280,
    pattern=("mla",),
    mla=MLAConfig(n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    # group_size 512: capacity/group = 1.25·512·8/256 = 20; dispatch cost
    # 2·cf·k·g·d ≈ 10% of the expert FFN math (§Perf iter 2 napkin).
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, group_size=512),
    moe_every=1,
    n_dense_prologue=3,
    subquadratic=True,     # MLA latent cache
)

SMOKE = CONFIG.scaled(
    name="deepseek-v3-671b-smoke", n_layers=3, d_model=64, d_ff=128,
    vocab_size=256, n_dense_prologue=1,
    mla=MLAConfig(n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared_experts=1),
)
