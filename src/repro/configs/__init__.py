"""Architecture registry: ``--arch <id>`` → ModelConfig.

One module per assigned architecture (full + smoke configs), plus the
paper's own MLP/sketch experiment configs in ``paper.py``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "stablelm-12b": "repro.configs.stablelm_12b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "granite-8b": "repro.configs.granite_8b",
    "command-r-35b": "repro.configs.command_r_35b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1b6",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "musicgen-large": "repro.configs.musicgen_large",
}

# (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def arch_names() -> List[str]:
    return list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {list(_MODULES)}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def cells(include_skipped: bool = False):
    """Yield (arch, shape) dry-run cells, honoring the long_500k rule."""
    for arch in _MODULES:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.subquadratic and not include_skipped:
                continue
            yield arch, shape
