"""llama-3.2-vision-11b — dense GQA with cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=128256.  Every 5th layer cross-attends to vision-encoder states; the
vision frontend is a STUB per the brief — ``input_specs`` supplies 1600
precomputed patch embeddings per sample.  Full attention → long_500k skipped.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=500000.0),
    n_encoder_tokens=1600,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="llama-3.2-vision-11b-smoke", n_layers=5, d_model=64, d_ff=128,
    vocab_size=256, n_encoder_tokens=16,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
)
