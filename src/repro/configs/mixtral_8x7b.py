"""mixtral-8x7b — MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088].

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), expert d_ff=14336,
vocab=32000, SWA window 4096.  Bounded KV (ring buffer) → long_500k RUNS.
"""

from repro.models.config import AttentionConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    d_ff=14336,            # unused (all layers MoE); kept for reference
    vocab_size=32000,
    pattern=("attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              window=4096, rope_theta=1000000.0),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    moe_every=1,
    subquadratic=True,     # SWA ⇒ bounded decode memory
)

SMOKE = CONFIG.scaled(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=64, d_ff=128,
    vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=8),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
)
