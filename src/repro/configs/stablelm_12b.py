"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-1_6b family].

40L, d_model=5120, 32 heads (GQA kv=8, head_dim=160), d_ff=13824,
vocab=100352.  Pure full attention → long_500k skipped (DESIGN.md §5).
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    d_ff=13824,
    vocab_size=100352,
    pattern=("attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=160,
                              rope_theta=10000.0),
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="stablelm-12b-smoke", n_layers=2, d_model=64, d_ff=128,
    vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
)
