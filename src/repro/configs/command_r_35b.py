"""command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=22528,
vocab=256000.  Pure full attention → long_500k skipped.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    d_ff=22528,
    vocab_size=256000,
    pattern=("attn",),
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_theta=8000000.0),
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="command-r-35b-smoke", n_layers=2, d_model=64, d_ff=128,
    vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
)
