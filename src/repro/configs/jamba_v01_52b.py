"""jamba-v0.1-52b — Mamba+attention 1:7 hybrid with MoE [arXiv:2403.19887].

32L, d_model=4096; each period of 8 layers has one attention layer
(position 3) and seven Mamba layers; MoE (16 experts, top-2, expert
d_ff=14336) on every second layer.  Recurrent Mamba state + a handful of
attention layers → long_500k RUNS (attention KV at 500k × 4 layers is the
dominant term; see EXPERIMENTS.md).
"""

from repro.models.config import (AttentionConfig, MambaConfig, MoEConfig,
                                 ModelConfig)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    pattern=("mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba", "mamba"),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              use_rope=False),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    moe_every=2,
    subquadratic=True,
)

SMOKE = CONFIG.scaled(
    name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, d_ff=128,
    vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                              use_rope=False),
    mamba=MambaConfig(d_state=4, d_conv=2, expand=2),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
)
