"""granite-8b — llama-arch dense GQA code model [arXiv:2405.04324].

36L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=49152, tied embeddings.  Pure full attention → long_500k skipped.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    n_layers=36,
    d_model=4096,
    d_ff=14336,
    vocab_size=49152,
    pattern=("attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=10000000.0),
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="granite-8b-smoke", n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
)
