"""rwkv6-1.6b — Finch, attention-free with data-dependent decay [arXiv:2404.05892].

24L, d_model=2048, d_ff=7168, vocab=65536.  Constant-size recurrent state
(B, H, 64, 64) → long_500k RUNS.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65536,
    pattern=("rwkv",),
    subquadratic=True,
)

SMOKE = CONFIG.scaled(
    name="rwkv6-1.6b-smoke", n_layers=2, d_model=64, d_ff=128, vocab_size=256,
)
