"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model=2048, 32 heads (MHA: kv=32, head_dim=64), d_ff=8192,
vocab=2048 (one EnCodec codebook; the multi-codebook delay pattern is a
frontend/scheduling detail stubbed per the brief — tokens arrive as a single
interleaved stream).  Full attention → long_500k skipped.

Note for the sketched-head feature (DESIGN.md §4): with vocab=2048 ≈ d_model
the dense head is already cheap; the sketch head is selectable but its win
is small here — measured in benchmarks/sketch_head_bench.py.
"""

from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab_size=2048,
    pattern=("attn",),
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64),
    subquadratic=False,
)

SMOKE = CONFIG.scaled(
    name="musicgen-large-smoke", n_layers=2, d_model=64, d_ff=128,
    vocab_size=64,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
)
