"""Parameter / activation / cache sharding rules (DESIGN.md §6).

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  Batch shards over (pod×)data; tensor dims over model:

* embedding & head      — vocab over ``model``
* attention q/k/v/o     — head dim over ``model``
* dense FFN             — d_ff over ``model``
* MoE experts           — expert axis over ``model`` (expert parallelism)
* mamba / rwkv inner    — d_inner / heads over ``model``
* KV & state caches     — batch over data axes, *sequence* over ``model``
                          (sequence parallelism: lets 500k-token caches fit)

Rules are path-based regexes over flattened parameter paths; scanned period
stacks get their leading ``n_periods`` axis automatically skipped.  ZeRO-1
(`zero1=True`) additionally shards optimizer-state leaves over ``data`` on
the largest remaining unsharded dimension.

Frozen *logit-head* params (the serving-side sketch family) have their own
rule table: ``head_param_shardings`` partitions the (L, R, V) RACE count
arrays over ``model`` on the repetition axis L and replicates the hash
params, so the sharded decode path reduces with one ``psum`` per step
(DESIGN.md §9).
"""

from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, spec WITHOUT the scan axis). First match wins.
_PARAM_RULES: Tuple[Tuple[str, P], ...] = (
    (r"embed$",                       P("model", None)),
    (r"head$",                        P("model", None)),
    (r"final_norm$",                  P(None)),
    # attention
    (r"mixer/w[qkv]$",                P(None, "model")),
    (r"mixer/wo$",                    P("model", None)),
    # MLA
    (r"mixer/w_dq$",                  P(None, None)),
    (r"mixer/w_uq$",                  P(None, "model")),
    (r"mixer/w_dkv$",                 P(None, None)),
    (r"mixer/w_u[kv]$",               P(None, "model")),
    (r"mixer/w_o$",                   P("model", None)),
    # FFN (dense 2-dim and MoE 3-dim share key names; candidates are
    # rank-filtered, and among rank matches the first fully-divisible spec
    # wins — EP on the expert axis with f-TP fallback when E < tp).
    (r"ffn/router$",                  P(None, None)),
    (r"ffn/shared/w_(gate|up)$",      P(None, "model")),
    (r"ffn/shared/w_down$",           P("model", None)),
    # Expert weights: EP over model + FSDP over data (ZeRO-3: stored
    # sharded, all-gathered per scan step — DeepSeek's 654B of experts is
    # 82 GB/device under EP alone, far over HBM; FSDP/16 → 5 GB).
    (r"ffn/w_(gate|up)$",             (P("model", "data", None),
                                       P("model", None, None),
                                       P(None, "data", "model"),
                                       P(None, None, "model"),
                                       P(None, "model"))),
    (r"ffn/w_down$",                  (P("model", "data", None),
                                       P("model", None, None),
                                       P(None, "model", "data"),
                                       P(None, "model", None),
                                       P("model", None))),
    # dense FFN
    (r"ffn/w_(gate|up)$",             P(None, "model")),
    (r"ffn/w_down$",                  P("model", None)),
    # mamba
    (r"mixer/in_proj$",               P(None, "model")),
    (r"mixer/conv_w$",                P(None, "model")),
    (r"mixer/conv_b$",                P("model")),
    (r"mixer/x_proj$",                P("model", None)),
    (r"mixer/dt_proj$",               P(None, "model")),
    (r"mixer/dt_bias$",               P("model")),
    (r"mixer/a_log$",                 P("model", None)),
    (r"mixer/d_skip$",                P("model")),
    (r"mixer/out_proj$",              P("model", None)),
    # rwkv
    (r"mixer/mu(_cm)?$",              P(None, None)),
    (r"mixer/w_[rkvg]$",              P(None, "model")),
    (r"mixer/w0$",                    P("model")),
    (r"mixer/w_lora_a$",              P(None, None)),
    (r"mixer/w_lora_b$",              P(None, "model")),
    (r"mixer/u_bonus$",               P("model", None)),
    (r"mixer/ln_x$",                  P("model")),
    (r"mixer/cm_[kr]$",               P(None, "model")),
    (r"mixer/cm_v$",                  P("model", None)),
    # norms & anything scalar
    (r"norm[12]$",                    P(None)),
    # sketch head embedded in a model tree (same layout as _HEAD_RULES:
    # count arrays over model on the repetition axis, hash params replicated)
    (r"sketch/array$",                P("model", None, None)),
    (r"sketch/scale$",                P("model", None)),
    (r"sketch/.*$",                   P(None)),
)


# Frozen sketch-head param tree ({"proj", "w", "b", "array"} — see
# core/sketch_lm_head.freeze_head).  The (L, R, V) count arrays partition
# over ``model`` on the repetition axis L: every shard owns L/m full RACE
# repetitions, so a decode step aggregates per-shard partial means and
# finishes with ONE psum of the (B, V) logits (the shard_map path in
# kernels/fused_decode and kernels/sketch_head).  Hash params (proj, w, b)
# are replicated — they are KB-scale and every shard slices its own L rows
# inside the shard_map.  First match wins; exactly one rule per leaf
# (tests/test_sharding.py).
_HEAD_RULES: Tuple[Tuple[str, P], ...] = (
    (r"(^|/)array$",                  P("model", None, None)),
    # Quantized heads: (L, R) per-row scales partition with their rows
    # (DESIGN.md §12).  int4 heads store a packed (⌈L/2⌉, R, V) array —
    # the same rule applies; _fit_spec falls back to replication when the
    # packed dim does not divide the model axis.
    (r"(^|/)scale$",                  P("model", None)),
    (r"(^|/)proj$",                   P(None, None)),
    (r"(^|/)w$",                      P(None, None, None)),
    (r"(^|/)b$",                      P(None, None)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of ``mesh``.

    Args:
      mesh: a ``jax.sharding.Mesh`` (or any object with ``axis_names``).

    Returns:
      The subset of ``("pod", "data")`` present in the mesh, in order.
    """
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded axes that don't divide; pad spec rank to the array rank."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([axes[n] for n in names]))
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def _fully_fits(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> bool:
    return tuple(_fit_spec(spec, shape, mesh)) == tuple(
        list(spec) + [None] * (len(shape) - len(spec)))


def param_spec(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
               scanned: bool) -> P:
    """PartitionSpec for one model-parameter leaf.

    Args:
      path_str: ``/``-joined flattened tree path (e.g.
        ``"periods/pos0/mixer/wq"``).
      shape: the leaf's array shape.
      mesh: target mesh; axis sizes gate divisibility fallbacks.
      scanned: whether the leaf carries a leading ``n_periods`` scan axis
        (the axis is skipped and never sharded).

    Returns:
      The first matching rule's spec, rank-filtered and divisibility-checked
      (``_fit_spec``); replicated if no rule matches.
    """
    rank = len(shape) - (1 if scanned else 0)
    for pattern, specs in _PARAM_RULES:
        if re.search(pattern, path_str):
            candidates = (specs,) if isinstance(specs, P) else tuple(specs)
            ranked = [s for s in candidates if len(s) == rank] or list(candidates)
            for spec in ranked:
                base = P(None, *spec) if scanned else spec
                if _fully_fits(base, shape, mesh):
                    return base
            base = P(None, *ranked[0]) if scanned else ranked[0]
            return _fit_spec(base, shape, mesh)
    return _fit_spec(P(), shape, mesh)


def params_shardings(params, mesh: Mesh):
    """NamedSharding pytree for a model parameter tree.

    Args:
      params: the model parameter pytree (``models.model.init_model``).
      mesh: target mesh.

    Returns:
      A pytree of ``NamedSharding`` with the same structure as ``params``.
    """
    def one(path, leaf):
        ps = _path_str(path)
        scanned = "periods/" in ps
        return NamedSharding(mesh, param_spec(ps, leaf.shape, mesh, scanned))
    return jax.tree_util.tree_map_with_path(one, params)


def head_rule_matches(path_str: str) -> Tuple[str, ...]:
    """Every ``_HEAD_RULES`` pattern matching a head-param leaf path.

    Exists so tests can assert the rule set is unambiguous (exactly one
    match per leaf of the frozen sketch-head tree — no silent replication
    of count arrays through the no-match fallback).

    Args:
      path_str: ``/``-joined flattened path of a head-param leaf.

    Returns:
      The matching rule patterns, in rule order.
    """
    return tuple(pat for pat, _ in _HEAD_RULES if re.search(pat, path_str))


def head_param_spec(path_str: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one frozen logit-head param leaf.

    Args:
      path_str: leaf path within the head tree (``"array"``, ``"proj"``, …).
      shape: the leaf's array shape.
      mesh: target mesh; if the repetition axis L does not divide the
        ``model`` axis size the spec falls back to replication.

    Returns:
      The first matching ``_HEAD_RULES`` spec (divisibility-checked);
      replicated for unknown leaf names.

    Raises:
      Nothing — unknown leaves replicate, so third-party head kinds with
      extra state serve unsharded rather than failing.
    """
    for pattern, spec in _HEAD_RULES:
        if re.search(pattern, path_str):
            return _fit_spec(spec, shape, mesh)
    return _fit_spec(P(), shape, mesh)


def head_param_shardings(head_params, mesh: Mesh):
    """NamedSharding pytree for a frozen logit-head param tree.

    The sketch family's (L, R, V) count arrays shard over ``model`` on the
    repetition axis; hash params replicate (see ``_HEAD_RULES``).  Used by
    ``repro.api.LM`` / the engine to place ``head.params`` on the serving
    mesh so the shard_map decode path starts from already-local shards.

    Args:
      head_params: the frozen head tree (``core.sketch_lm_head.freeze_head``).
      mesh: target mesh.

    Returns:
      A pytree of ``NamedSharding`` mirroring ``head_params``.
    """
    def one(path, leaf):
        return NamedSharding(
            mesh, head_param_spec(_path_str(path), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, head_params)


def head_bank_shardings(bank, mesh: Mesh):
    """NamedSharding dict for a tenant-stacked head bank (DESIGN.md §14).

    A ``HeadCache`` bank is a frozen head tree with a leading tenant axis T
    on every leaf (``(T, L, R, V)`` count arrays, ``(T, L, R)`` scales,
    ``(T, d, d')`` transforms, …).  The tenant axis is never sharded —
    decode slices one tenant's row at a time and each slice must be exactly
    a single-tenant head shard — so every leaf keeps ``head_param_spec`` on
    its trailing dims with ``None`` prepended.  A ``"tenant_ids"`` leaf
    (the (B,) slot binding) replicates.

    Args:
      bank: dict of tenant-stacked head leaves (``HeadCache`` internal bank,
        optionally including ``"tenant_ids"``).
      mesh: target mesh.

    Returns:
      ``{leaf name: NamedSharding}`` mirroring ``bank``.
    """
    out = {}
    for name, leaf in bank.items():
        if name == "tenant_ids":
            out[name] = NamedSharding(mesh, P(None))
            continue
        inner = head_param_spec(name, leaf.shape[1:], mesh)
        out[name] = NamedSharding(mesh, P(None, *inner))
    return out


def zero1_shardings(params, mesh: Mesh):
    """Optimizer-state sharding: param spec + `data` on the largest free dim.

    Args:
      params: the model parameter pytree (state leaves mirror it).
      mesh: target mesh.

    Returns:
      A pytree of ``NamedSharding``: each leaf keeps its ``param_spec`` and
      additionally shards the largest unsharded divisible dim over the data
      axes (ZeRO-1); FSDP-sharded leaves are left as-is.
    """
    dax = data_axes(mesh)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsize = int(np.prod([axes[a] for a in dax]))

    def one(path, leaf):
        ps = _path_str(path)
        scanned = "periods/" in ps
        spec = list(param_spec(ps, leaf.shape, mesh, scanned))
        spec += [None] * (len(leaf.shape) - len(spec))
        # FSDP-sharded params already use the data axes — state follows.
        used = {n for e in spec if e is not None
                for n in (e if isinstance(e, tuple) else (e,))}
        if used & set(dax):
            return NamedSharding(mesh, P(*spec))
        # Pick the largest unsharded, divisible dim for the data axes.
        best, best_dim = -1, -1
        for i, (dim, entry) in enumerate(zip(leaf.shape, spec)):
            if entry is None and dim % dsize == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0 and dsize > 1:
            spec[best] = dax if len(dax) > 1 else dax[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(batch_size: int, mesh: Mesh) -> P:
    """Spec entry for a batch axis: (pod, data) if divisible, else what fits.

    Args:
      batch_size: the batch dimension to shard.
      mesh: target mesh.

    Returns:
      The axis-name entry (tuple / str / ``None``) to place in a
      ``PartitionSpec`` for the batch dimension — all data axes when they
      divide ``batch_size``, ``"data"`` alone as a fallback, else ``None``.
    """
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = data_axes(mesh)
    total = int(np.prod([axes[a] for a in dax]))
    if batch_size % total == 0:
        return dax if len(dax) > 1 else dax[0]
    # try data only (drop pod), then nothing
    if "data" in axes and batch_size % axes["data"] == 0:
        return "data"
    return None


def cache_shardings(cache, mesh: Mesh, batch_size: Optional[int] = None):
    """Decode-cache sharding: batch over data axes, *features* over model.

    Args:
      cache: a decode-cache pytree (``models.model.init_decode_cache`` or an
        abstract ``eval_shape`` of one).
      mesh: target mesh.
      batch_size: the cache's batch (slot-pool) size, used for the
        batch-axis divisibility check.  ``None`` infers it per leaf from the
        leading batch dimension — every leaf of one cache shares the same B,
        so this is equivalent and lets jitted steps constrain their output
        cache without threading B statically.

    Returns:
      A pytree of ``NamedSharding`` mirroring ``cache`` (``None`` subtrees
      preserved).

    The sequence axis is deliberately never sharded: the per-step
    ``dynamic_update_slice`` at a traced position does not partition across
    a sharded dim.  Instead each cache type shards a feature dim
    (SP-for-memory via heads / head_dim / latent rank):

      attention KVCache k/v  (B, S, kv, dh) → kv over model if divisible,
                                              else dh over model
      MLA c_kv / k_rope      (B, S, r)      → r over model
      mamba conv             (B, c-1, d_in) → d_in over model
      mamba ssm              (B, d_in, N)   → d_in over model
      rwkv prev vectors      (B, d)         → d over model
      rwkv state             (B, H, dk, dv) → H over model

    Scanned caches carry a leading n_periods axis (skipped).  All specs are
    divisibility-checked by _fit_spec.
    """
    from repro.models.attention import KVCache
    from repro.models.mamba import MambaCache
    from repro.models.mla import MLACache
    from repro.models.rwkv import RWKVCache

    bspec_global = None if batch_size is None else batch_spec(batch_size, mesh)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = axes.get("model", 1)

    def leaf_spec(kind_field, shape, scanned):
        rank = len(shape) - (1 if scanned else 0)
        dims = shape[1:] if scanned else shape
        bspec = (batch_spec(dims[0], mesh) if batch_size is None
                 else bspec_global)
        if kind_field == "kv":          # (B, S, kv, dh)
            if dims[2] % msize == 0:
                spec = P(bspec, None, "model", None)
            else:
                spec = P(bspec, None, None, "model")
        elif kind_field == "mla":       # (B, S, r)
            spec = P(bspec, None, "model")
        elif kind_field == "mamba_conv":
            spec = P(bspec, None, "model")
        elif kind_field == "mamba_ssm":  # (B, d_in, N)
            spec = P(bspec, "model", None)
        elif kind_field == "rwkv_prev":  # (B, d)
            spec = P(bspec, "model")
        elif kind_field == "rwkv_state":  # (B, H, dk, dv)
            spec = P(bspec, "model", None, None)
        else:
            spec = P(bspec, *([None] * (rank - 1)))
        if scanned:
            spec = P(None, *spec)
        return NamedSharding(mesh, _fit_spec(spec, shape, mesh))

    def rec(node, scanned):
        if node is None:
            return None
        if isinstance(node, KVCache):
            return KVCache(leaf_spec("kv", node.k.shape, scanned),
                           leaf_spec("kv", node.v.shape, scanned))
        if isinstance(node, MLACache):
            return MLACache(leaf_spec("mla", node.c_kv.shape, scanned),
                            leaf_spec("mla", node.k_rope.shape, scanned))
        if isinstance(node, MambaCache):
            return MambaCache(leaf_spec("mamba_conv", node.conv.shape, scanned),
                              leaf_spec("mamba_ssm", node.ssm.shape, scanned))
        if isinstance(node, RWKVCache):
            return RWKVCache(leaf_spec("rwkv_prev", node.tm_prev.shape, scanned),
                             leaf_spec("rwkv_prev", node.cm_prev.shape, scanned),
                             leaf_spec("rwkv_state", node.state.shape, scanned))
        if isinstance(node, dict):
            return {k: rec(v, scanned or k == "periods") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, scanned) for v in node)
        return leaf_spec("other", node.shape, scanned)

    return rec(cache, False)


def page_pool_shardings(pages, mesh: Mesh):
    """Sharding for the paged-cache arena tree (DESIGN.md §13).

    Args:
      pages: a page-arena pytree (``models.model.init_paged_cache`` or an
        abstract ``eval_shape`` of one) — attention/MLA leaves shaped
        ``(num_pages, page_size, …)``, None at recurrent/cacheless layers.
      mesh: target mesh.

    Returns:
      A pytree of ``NamedSharding`` mirroring ``pages`` (None preserved).

    Feature dims shard over ``model`` exactly as the contiguous
    ``cache_shardings`` leaves do (kv heads / head_dim, latent rank), so the
    gathered per-slot view lands in the same layout the decode step
    constrains its cache to.  The page and in-page axes are replicated: page
    ids are host-chosen and non-contiguous, so a sharded page axis would
    turn every gather/commit into cross-device traffic.  Scanned periods
    carry the usual leading ``n_periods`` axis (skipped).
    """
    from repro.models.attention import KVCache
    from repro.models.mla import MLACache

    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = axes.get("model", 1)

    def leaf_spec(kind_field, shape, scanned):
        dims = shape[1:] if scanned else shape
        if kind_field == "kv":          # (N, ps, kv, dh)
            if dims[2] % msize == 0:
                spec = P(None, None, "model", None)
            else:
                spec = P(None, None, None, "model")
        else:                           # mla: (N, ps, r)
            spec = P(None, None, "model")
        if scanned:
            spec = P(None, *spec)
        return NamedSharding(mesh, _fit_spec(spec, shape, mesh))

    def rec(node, scanned):
        if node is None:
            return None
        if isinstance(node, KVCache):
            return KVCache(leaf_spec("kv", node.k.shape, scanned),
                           leaf_spec("kv", node.v.shape, scanned))
        if isinstance(node, MLACache):
            return MLACache(leaf_spec("mla", node.c_kv.shape, scanned),
                            leaf_spec("mla", node.k_rope.shape, scanned))
        if isinstance(node, dict):
            return {k: rec(v, scanned or k == "periods") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v, scanned) for v in node)
        raise TypeError(f"unexpected paged-arena leaf {type(node)}")

    return rec(pages, False)
