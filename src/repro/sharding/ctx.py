"""Activation-sharding context: logical-axis constraints inside model code.

Model code calls ``constrain(x, "dp", None, "tp", ...)`` with *logical* axis
names; when an activation-sharding context is active (set by the launcher
around tracing), these map to the physical mesh axes

    "dp" → ("pod", "data")   (whatever data axes the mesh has)
    "tp" → "model"

and become ``jax.lax.with_sharding_constraint`` calls — the Megatron-style
pattern that pins the FFN intermediate to TP shards, activations to DP
shards, etc., so the SPMD partitioner can't pick pathological strategies
(e.g. contraction-sharded FFN with a d_ff-wide all-reduce, observed in the
baseline — see EXPERIMENTS.md §Perf iteration 1).

Outside a context (unit tests, single-host smoke) ``constrain`` is a no-op.
Axes that do not divide the corresponding dimension are dropped per-call, so
the same model code serves every (arch × shape × mesh) cell.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@contextmanager
def activation_sharding(mesh: Mesh):
    """Enable logical-axis activation constraints while tracing."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in axes)
    logical = {
        "dp": dp if len(dp) != 1 else dp[0],
        "tp": "model" if "model" in axes else None,
    }
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, axes, logical)
    try:
        yield
    finally:
        _state.ctx = prev


def _axis_size(axes: dict, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([axes[n] for n in names]))


def logical_axis_size(name: str) -> int:
    """Size of a logical axis ('dp'/'tp') in the active context (1 if none)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return 1
    _, axes, logical = ctx
    return _axis_size(axes, logical.get(name))


def constrain(x, *logical_spec):
    """Apply a sharding constraint using logical axis names ('dp'/'tp'/None).

    No-op when no context is active.  Drops any axis whose size does not
    divide the dimension (so callers never special-case shapes).
    """
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, axes, logical = ctx
    entries = []
    for dim, name in zip(x.shape, logical_spec):
        phys = logical.get(name) if name else None
        if phys is None or dim % _axis_size(axes, phys) != 0:
            entries.append(None)
        else:
            entries.append(phys)
    entries += [None] * (x.ndim - len(entries))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
