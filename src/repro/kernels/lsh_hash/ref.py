"""Pure-jnp oracle for the fused LSH hash kernel.

Computes, for a batch of queries, the (B, L) int32 bucket indices of the
concatenated p-stable LSH bank:

    proj   = x @ w^T + b          # (B, L·K)
    codes  = floor(proj / r)      # int32 sub-hash codes
    idx    = fold_K(codes) mod R  # universal rehash of the K codes per row

Must match repro.core.lsh.L2LSH.hash bit-for-bit (same mixing constants).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.lsh import _fold_subhashes


def lsh_hash_ref(
    x: jnp.ndarray,      # (B, d) float32
    w: jnp.ndarray,      # (L, K, d) float32
    b: jnp.ndarray,      # (L, K) float32
    bandwidth: float,
    n_buckets: int,
    row_salt: jnp.ndarray | None = None,  # (L,) uint32 global-row fold salts
) -> jnp.ndarray:        # (B, L) int32
    proj = jnp.einsum("bd,lkd->blk", x, w)
    codes = jnp.floor((proj + b) / bandwidth).astype(jnp.int32)
    return _fold_subhashes(codes, n_buckets, salt=row_salt)
