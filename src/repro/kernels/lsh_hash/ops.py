"""Public wrapper for the fused LSH hash kernel (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.lsh_hash.kernel import lsh_hash_pallas
from repro.kernels.lsh_hash.ref import lsh_hash_ref


@registry.register("lsh_hash", "pallas")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "block_b"))
def _pallas(x, w, b, *, bandwidth, n_buckets, block_b):
    return lsh_hash_pallas(x, w, b, bandwidth=bandwidth, n_buckets=n_buckets,
                           block_b=block_b)


@registry.register("lsh_hash", "ref")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "block_b"))
def _ref(x, w, b, *, bandwidth, n_buckets, block_b):
    del block_b  # tiling is a pallas concern
    return lsh_hash_ref(x, w, b, bandwidth, n_buckets)


def lsh_hash(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bandwidth: float,
    n_buckets: int,
    block_b: int = 128,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Bucket indices (B, L) for a batch of queries against an L×K LSH bank."""
    impl = registry.resolve("lsh_hash", backend, use_pallas)
    return impl(x, w, b, bandwidth=bandwidth, n_buckets=n_buckets,
                block_b=block_b)
