"""Jit'd public wrapper for the fused LSH hash kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.lsh_hash.kernel import lsh_hash_pallas
from repro.kernels.lsh_hash.ref import lsh_hash_ref


@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "block_b", "use_pallas"))
def lsh_hash(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bandwidth: float,
    n_buckets: int,
    block_b: int = 128,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Bucket indices (B, L) for a batch of queries against an L×K LSH bank."""
    if use_pallas:
        return lsh_hash_pallas(
            x, w, b, bandwidth=bandwidth, n_buckets=n_buckets, block_b=block_b
        )
    return lsh_hash_ref(x, w, b, bandwidth, n_buckets)
