"""Fused LSH-hash Pallas kernel: projection (MXU) + floor + K-fold rehash.

TPU mapping (DESIGN.md §3): the edge-oriented 'add/sub only' sparse hash of
the paper becomes a dense bf16/f32 matmul on the MXU — a (Bt, d)·(d, L·K)
tile — followed by VPU-side quantization and integer mixing, all inside one
kernel so the (B, L·K) projection never round-trips to HBM.

Tiling:
  grid = (B / Bt,)
  x:    (Bt, d)    VMEM  block
  w:    (L·K, d)   VMEM  (whole bank resident; L·K·d ≤ ~6k·128 floats ≈ 3 MB)
  b:    (1, L·K)   VMEM
  out:  (Bt, L)    VMEM

The K sub-hash codes of each row are folded with the same Carter–Wegman-style
integer mix as repro.core.lsh._fold_subhashes (bit-exact parity is asserted
in tests against ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis, round_up

_MIX_A = 1103515245


def _mix_codes(codes: jnp.ndarray, k: int, n_buckets: int,
               salt: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fold (..., L, K) uint32 codes → (..., L) indices. Mirrors core.lsh
    bit-for-bit, including the golden-ratio per-row salt.  ``salt`` ((L,)
    uint32) overrides the local-row default — required when the caller only
    holds a row *slice* of the bank (the sharded fused-decode path), since
    the salt is a function of the global row index."""
    if salt is None:
        salt = (jax.lax.broadcasted_iota(jnp.uint32, codes.shape[:-1],
                                         codes.ndim - 2)
                * jnp.uint32(0x9E3779B9))
    acc = jnp.broadcast_to(salt, codes.shape[:-1]).astype(jnp.uint32)
    for i in range(k):
        acc = acc * jnp.uint32(_MIX_A & 0xFFFFFFFF) + codes[..., i] + jnp.uint32(i * 97 + 13)
        acc = acc ^ (acc >> 16)
        acc = acc * jnp.uint32(0x45D9F3B)
        acc = acc ^ (acc >> 16)
    return (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


def _lsh_hash_kernel(x_ref, w_ref, b_ref, out_ref, *, k: int, n_buckets: int,
                     bandwidth: float, n_rows: int):
    x = x_ref[...]                       # (Bt, d)
    w = w_ref[...]                       # (L*K, d)
    b = b_ref[...]                       # (1, L*K)
    # MXU: (Bt, d) @ (d, L*K)
    proj = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                    # (Bt, L*K)
    codes = jnp.floor((proj + b) / bandwidth).astype(jnp.int32).astype(jnp.uint32)
    codes = codes.reshape(codes.shape[0], n_rows, k)
    out_ref[...] = _mix_codes(codes, k, n_buckets)


def lsh_hash_pallas(
    x: jnp.ndarray,          # (B, d) f32
    w: jnp.ndarray,          # (L, K, d) f32
    b: jnp.ndarray,          # (L, K) f32
    *,
    bandwidth: float,
    n_buckets: int,
    block_b: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:            # (B, L) int32
    if interpret is None:
        interpret = interpret_default()
    n_batch, d = x.shape
    n_rows, k, _ = w.shape

    w2 = w.reshape(n_rows * k, d)
    b2 = b.reshape(1, n_rows * k)

    xp = pad_axis(x, 0, block_b)
    bp = xp.shape[0]
    grid = (bp // block_b,)

    out = pl.pallas_call(
        functools.partial(
            _lsh_hash_kernel, k=k, n_buckets=n_buckets,
            bandwidth=bandwidth, n_rows=n_rows,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((n_rows * k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, n_rows * k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_rows), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n_rows), jnp.int32),
        interpret=interpret,
    )(xp, w2, b2)
    return out[:n_batch]
