"""Flash-attention Pallas kernel — fused online-softmax causal prefill.

The §Roofline analysis charges the prefill cells for materializing the
(Sq, Sk) score tensor through HBM; this kernel keeps scores in VMEM,
computing one (Bq × Bk) tile at a time with the flash-v2 recurrence
(running row-max m, denominator l, and un-normalized accumulator acc).

Grid & tiling (one head-batch per grid row; MXU-aligned tiles):

  grid = (B·H, Sq / Bq, Sk / Bk)           — Bk innermost: acc stays in VMEM
  q:   (1, Bq, dh)    VMEM
  k,v: (1, Bk, dh)    VMEM
  out: (1, Bq, dh)    VMEM  (revisited across the Bk axis)
  m,l: (1, Bq)        VMEM scratch carried across Bk steps

Causal + sliding-window masking is applied per tile from the absolute tile
offsets; fully-masked tiles are skipped with ``pl.when`` (the triangular /
banded structure is why this beats the XLA-lowered scan in both FLOPs and
bytes).  Gemma-2-style score softcap is fused.

Validated in interpret mode against ref.py over shape/window/softcap sweeps
(tests/test_kernels.py::test_flash_attention_*).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, round_up

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_len: int,
                  window: Optional[int], softcap: Optional[float]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_start = qi * block_q
    k_start = ki * block_k

    # Tile-level structure: skip tiles strictly above the causal diagonal
    # or strictly outside the sliding window band.
    causal_live = k_start <= q_start + block_q - 1
    window_live = (True if window is None
                   else k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(causal_live & window_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (Bq, dh)
        k = k_ref[0].astype(jnp.float32)          # (Bk, dh)
        v = v_ref[0].astype(jnp.float32)          # (Bk, dh)
        dh = q.shape[-1]
        s = jax.lax.dot_general(q * (dh ** -0.5), k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = (q_pos >= k_pos) & (k_pos < seq_len)
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[0]                          # (Bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1)
        acc_ref[0] = (acc_ref[0] * corr[:, None]
                      + jax.lax.dot_general(
                          p, v, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
        m_ref[0] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[0]
                    / jnp.maximum(l_ref[0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, S, H, dh)
    v: jnp.ndarray,          # (B, S, H, dh)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = interpret_default()
    b, s, h, dh = q.shape
    block_q = min(block_q, round_up(s, 8))
    block_k = min(block_k, round_up(s, 8))

    # (B·H, S, dh) layout; pad S to the tile size.
    def fold(t):
        t = jnp.swapaxes(t, 1, 2).reshape(b * h, s, dh)
        pad = (-s) % max(block_q, block_k)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        return t

    qf, kf, vf = fold(q), fold(k), fold(v)
    sp = qf.shape[1]
    grid = (b * h, sp // block_q, sp // block_k)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_len=s, window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, block_q), jnp.float32),      # m
            pltpu.VMEM((1, block_q), jnp.float32),      # l
            pltpu.VMEM((1, block_q, dh), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :s].reshape(b, h, s, dh)
    return jnp.swapaxes(out, 1, 2)
