"""Pure-jnp oracle for the flash-attention prefill kernel: plain masked
softmax attention (causal + optional sliding window + optional softcap)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray,          # (B, S, H, dh)
    k: jnp.ndarray,          # (B, S, H, dh)  (KV pre-expanded to full heads)
    v: jnp.ndarray,          # (B, S, H, dh)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:            # (B, S, H, dh)
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if softcap:
        scores = softcap_fn(scores, softcap)
    pos = jnp.arange(s)
    mask = pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= (pos[:, None] - pos[None, :]) < window
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def softcap_fn(x, cap):
    return cap * jnp.tanh(x / cap)
