"""Public wrapper for the flash-attention prefill kernel (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@registry.register("flash_attn", "pallas")
@partial(jax.jit, static_argnames=("window", "softcap", "block_q", "block_k"))
def _pallas(q, k, v, *, window, softcap, block_q, block_k):
    return flash_attention_pallas(q, k, v, window=window, softcap=softcap,
                                  block_q=block_q, block_k=block_k)


@registry.register("flash_attn", "ref")
@partial(jax.jit, static_argnames=("window", "softcap", "block_q", "block_k"))
def _ref(q, k, v, *, window, softcap, block_q, block_k):
    del block_q, block_k  # tiling is a pallas concern
    return flash_attention_ref(q, k, v, window=window, softcap=softcap)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Fused causal (+window, +softcap) attention: (B,S,H,dh)³ → (B,S,H,dh)."""
    impl = registry.resolve("flash_attn", backend, use_pallas)
    return impl(q, k, v, window=window, softcap=softcap,
                block_q=block_q, block_k=block_k)
