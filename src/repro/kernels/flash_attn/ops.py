"""Jit'd public wrapper for the flash-attention prefill kernel."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("window", "softcap", "block_q", "block_k",
                                   "use_pallas"))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Fused causal (+window, +softcap) attention: (B,S,H,dh)³ → (B,S,H,dh)."""
    if use_pallas:
        return flash_attention_pallas(q, k, v, window=window, softcap=softcap,
                                      block_q=block_q, block_k=block_k)
    return flash_attention_ref(q, k, v, window=window, softcap=softcap)
