"""Pure-jnp oracle for the sketched LM-head decode kernel.

The sketched head stores, per vocab class v, a RACE array column; laid out as
``S ∈ (L, R, V)`` so all classes share the L row reads of a query (the hash
indices h_l(q) are class-independent).  The logit estimate is the plain
row-mean (the paper notes mean ≈ MoM empirically; the mean keeps the head a
single matvec-like reduction on TPU — see kernel.py):

    logits[b, v] = 1/L · Σ_l  S[l, h_l(q_b), v]
"""

from __future__ import annotations

import jax.numpy as jnp


def sketch_head_ref(
    sketch: jnp.ndarray,   # (L, R, V) f32
    idx: jnp.ndarray,      # (B, L) int32
) -> jnp.ndarray:          # (B, V)
    l, r, v = sketch.shape
    # reads[b, l, v] = sketch[l, idx[b, l], v]
    reads = jnp.take_along_axis(
        sketch[None],              # (1, L, R, V)
        idx[:, :, None, None],     # (B, L, 1, 1)
        axis=2,
    )[:, :, 0, :]                  # (B, L, V)
    return jnp.mean(reads, axis=1)
