"""Pure-jnp oracle for the sketched LM-head decode kernel.

The sketched head stores, per vocab class v, a RACE array column; laid out as
``S ∈ (L, R, V)`` so all classes share the L row reads of a query (the hash
indices h_l(q) are class-independent).  The logit estimate is the plain
row-mean (the paper notes mean ≈ MoM empirically; the mean keeps the head a
single matvec-like reduction on TPU — see kernel.py):

    logits[b, v] = 1/L · Σ_l  S[l, h_l(q_b), v]

Quantized storage (DESIGN.md §12): ``sketch`` may arrive int8 (per-row
symmetric quantization) or packed int4 (two L-rows per byte) with an
``(L, R)`` f32 ``scale``.  The oracle simply materializes the dequantized
f32 array and reuses the f32 path — it is the *oracle*; the Pallas kernel is
the one that must keep dequantization in-register.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.common import unpack_int4_rows


def dequantize_sketch_ref(
    sketch: jnp.ndarray,     # int8: (L, R, V) or int4-packed (⌈L/2⌉, R, V)
    scale: jnp.ndarray,      # (L, R) f32 per-row scales
    quant: str,              # "int8" | "int4"
) -> jnp.ndarray:            # (L, R, V) f32
    """Materialized f32 counts from quantized storage (oracle/debug only)."""
    n_rows = scale.shape[0]
    if quant == "int4":
        sketch = unpack_int4_rows(sketch, n_rows)
    return sketch.astype(jnp.float32) * scale[:, :, None]


def sketch_head_ref(
    sketch: jnp.ndarray,   # (L, R, V) f32 | quantized (see dequantize)
    idx: jnp.ndarray,      # (B, L) int32
    scale: Optional[jnp.ndarray] = None,   # (L, R) f32 when quantized
    quant: Optional[str] = None,           # None | "int8" | "int4"
) -> jnp.ndarray:          # (B, V)
    if quant is not None:
        sketch = dequantize_sketch_ref(sketch, scale, quant)
    l, r, v = sketch.shape
    # reads[b, l, v] = sketch[l, idx[b, l], v]
    reads = jnp.take_along_axis(
        sketch[None],              # (1, L, R, V)
        idx[:, :, None, None],     # (B, L, 1, 1)
        axis=2,
    )[:, :, 0, :]                  # (B, L, V)
    return jnp.mean(reads, axis=1)
