"""Public wrapper for the sketched LM head (registry-dispatched).

``mesh=`` enables the sharded decode path (DESIGN.md §9): the (L, R, V)
count arrays are partitioned over the mesh's ``model`` axis on the
repetition axis L, every shard runs the same kernel on its local rows, and
the per-shard partial means finish with a single ``psum`` of the (B, V)
logits.  Falls back to the single-device path when L does not divide the
``model`` axis size.

Quantized storage (``quant="int8"|"int4"``, DESIGN.md §12) threads the
(L, R) f32 ``scale`` alongside the integer count array; under the mesh the
scales partition with their rows (``P("model", None)``).  int4 packs two
L-rows per byte, so its storage axis is ⌈L/2⌉ — the sharded path
additionally requires shard boundaries to land on byte boundaries
(L/msize even) and falls back to the replicated path otherwise.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.kernels.common import mesh_axis_size, select_tenant_rows
from repro.kernels.sketch_head.kernel import sketch_head_pallas
from repro.kernels.sketch_head.ref import sketch_head_ref


@registry.register("sketch_head", "pallas")
@partial(jax.jit, static_argnames=("quant", "block_b", "block_v"))
def _pallas(sketch, idx, scale=None, *, quant=None, block_b, block_v):
    return sketch_head_pallas(sketch, idx, scale, quant=quant,
                              block_b=block_b, block_v=block_v)


@registry.register("sketch_head", "ref")
@partial(jax.jit, static_argnames=("quant", "block_b", "block_v"))
def _ref(sketch, idx, scale=None, *, quant=None, block_b, block_v):
    del block_b, block_v  # tiling is a pallas concern
    return sketch_head_ref(sketch, idx, scale, quant)


def sketch_head_logits(
    sketch: jnp.ndarray,   # (L, R, V) f32 | (Lstore, R, V) int8 when quant
    idx: jnp.ndarray,      # (B, L)
    *,
    scale: Optional[jnp.ndarray] = None,   # (L, R) f32 when quantized
    quant: Optional[str] = None,           # None | "int8" | "int4"
    block_b: int = 8,
    block_v: int = 2048,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
    mesh=None,
    tenant_ids: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Estimate (B, V) logits from precomputed bucket indices.

    Args:
      sketch: the per-class RACE count arrays — (L, R, V) f32, or for
        ``quant`` the int8 carrier ((L, R, V) int8 / (⌈L/2⌉, R, V) packed
        int4 bytes).
      idx: (B, L) int32 bucket indices from ``lsh_hash``.
      scale: (L, R) f32 per-row dequantization scales (required iff
        ``quant`` is set).
      quant: ``None`` (f32 counts), ``"int8"`` or ``"int4"`` — static.
      block_b / block_v: pallas VMEM tile sizes.
      use_pallas: deprecated pallas/ref switch (prefer ``backend``).
      backend: kernel registry backend (``"pallas"`` / ``"ref"``); ``None``
        resolves through the registry default.
      mesh: a ``jax.sharding.Mesh`` with a ``model`` axis to run the
        row-sharded psum path; ``None`` (default) is the single-device path.
      tenant_ids: (B,) int32 per-slot tenant indices for the multi-tenant
        path (DESIGN.md §14).  When set, ``sketch`` is (T, L, R, V),
        ``scale`` (T, L, R), and ``idx`` (T, B, L) — each tenant's own hash
        bank produced the indices, so the stack carries one full-batch
        index tensor per tenant.  Every tenant evaluates through this same
        single-tenant path (shard_map psum included) and row ``b`` is
        selected from tenant ``tenant_ids[b]``'s stack arithmetic-free.

    Returns:
      (B, V) f32 logit estimates (the row-mean over L sketch reads).
    """
    if (scale is None) != (quant is None):
        raise ValueError("quant and scale must be passed together "
                         f"(quant={quant!r}, scale is "
                         f"{'None' if scale is None else 'set'})")
    if tenant_ids is not None:
        if idx.ndim != 3 or idx.shape[0] != sketch.shape[0]:
            raise ValueError(
                f"tenant_ids needs a (T, B, L) index stack matching the "
                f"(T, …) sketch bank; got idx {idx.shape} vs sketch "
                f"{sketch.shape}")
        per_tenant = jnp.stack([
            sketch_head_logits(
                sketch[t], idx[t],
                scale=None if scale is None else scale[t], quant=quant,
                block_b=block_b, block_v=block_v, use_pallas=use_pallas,
                backend=backend, mesh=mesh)
            for t in range(sketch.shape[0])])
        return select_tenant_rows(per_tenant, tenant_ids)
    impl = registry.resolve("sketch_head", backend, use_pallas)
    l = idx.shape[1]
    l_store = sketch.shape[0]
    msize = mesh_axis_size(mesh, "model")
    shardable = msize > 1 and l % msize == 0 and l_store % msize == 0
    if quant == "int4":
        # Byte-aligned shards only: no pad row, even true rows per shard.
        shardable = shardable and 2 * l_store == l
    if shardable:
        l_shard = l // msize
        # Keep the batch sharded over data when it divides (decode caches
        # already are): each device reads only its rows' indices and the
        # psum moves (B/d, V), not (B, V).
        dsize = mesh_axis_size(mesh, "data")
        bspec = "data" if dsize > 1 and idx.shape[0] % dsize == 0 else None

        if quant is None:
            def local(sk, ix):
                part = impl(sk, ix, block_b=block_b, block_v=block_v)
                return jax.lax.psum(part * (l_shard / l), "model")
            in_specs = (P("model", None, None), P(bspec, "model"))
            operands = (sketch, idx)
        else:
            def local(sk, ix, sc):
                part = impl(sk, ix, sc, quant=quant,
                            block_b=block_b, block_v=block_v)
                return jax.lax.psum(part * (l_shard / l), "model")
            in_specs = (P("model", None, None), P(bspec, "model"),
                        P("model", None))
            operands = (sketch, idx, scale)

        # check_rep=False: pallas_call has no replication rule; the psum
        # makes the output replicated over model by construction.
        return shard_map(
            local, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(bspec, None), check_rep=False)(*operands)
    return impl(sketch, idx, scale, quant=quant,
                block_b=block_b, block_v=block_v)
