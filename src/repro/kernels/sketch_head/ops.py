"""Public wrapper for the sketched LM head (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.lsh_hash.ops import lsh_hash
from repro.kernels.sketch_head.kernel import sketch_head_pallas
from repro.kernels.sketch_head.ref import sketch_head_ref


@registry.register("sketch_head", "pallas")
@partial(jax.jit, static_argnames=("block_b", "block_v"))
def _pallas(sketch, idx, *, block_b, block_v):
    return sketch_head_pallas(sketch, idx, block_b=block_b, block_v=block_v)


@registry.register("sketch_head", "ref")
@partial(jax.jit, static_argnames=("block_b", "block_v"))
def _ref(sketch, idx, *, block_b, block_v):
    del block_b, block_v  # tiling is a pallas concern
    return sketch_head_ref(sketch, idx)


def sketch_head_logits(
    sketch: jnp.ndarray,   # (L, R, V)
    idx: jnp.ndarray,      # (B, L)
    *,
    block_b: int = 8,
    block_v: int = 2048,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Estimate (B, V) logits from precomputed bucket indices."""
    impl = registry.resolve("sketch_head", backend, use_pallas)
    return impl(sketch, idx, block_b=block_b, block_v=block_v)


def sketch_head_apply(
    hidden: jnp.ndarray,   # (B, d_model) — final hidden state
    proj: jnp.ndarray,     # (d_model, d') asymmetric transform A
    w: jnp.ndarray,        # (L, K, d') hash projections
    b: jnp.ndarray,        # (L, K) hash offsets
    sketch: jnp.ndarray,   # (L, R, V) per-class arrays
    *,
    bandwidth: float,
    n_buckets: int,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Full sketched head: transform → hash → per-class RACE estimate."""
    q = hidden @ proj
    idx = lsh_hash(q, w, b, bandwidth=bandwidth, n_buckets=n_buckets,
                   use_pallas=use_pallas, backend=backend)
    return sketch_head_logits(sketch, idx, use_pallas=use_pallas,
                              backend=backend)
