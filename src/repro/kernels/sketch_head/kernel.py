"""Sketched LM-head Pallas kernel — per-class RACE estimate for decode.

This is the framework integration of the paper's technique (DESIGN.md §4):
at decode time the dense d_model×V logit matmul (2·d·V FLOPs/token) is
replaced by an L-row sketch lookup shared across all V classes
(L·V adds/token; L ≪ 2·d).

The class-sharing layout (L, R, V) turns the per-class gather into a single
(1, L·R)·(L·R, Vt) one-hot contraction per vocab tile — an MXU matvec whose
left operand has exactly L nonzeros.  VMEM tiling:

  grid = (B / Bt, V / Vt)
  idx:    (Bt, L)       VMEM
  sketch: (L, R, Vt)    VMEM  — vocab-tiled; with L=64, R=16, Vt=2048 this is
                               64·16·2048·4 B = 8 MB ≤ VMEM; shrink Vt to fit.
  out:    (Bt, Vt)      VMEM

Quantized storage (DESIGN.md §12): with ``quant`` set, HBM holds the count
array as int8 (per-row symmetric) or packed int4 (two L-rows per byte along
axis 0) plus tiny (L, R) f32 scales.  Dequantization never round-trips
through HBM — each VMEM tile is consumed directly by folding the row scales
into the one-hot left operand:

  out = (onehot ⊙ scale) · q_f32        (term-wise equal to scale·q gather)

so the f32 counts exist only as MXU operands; HBM traffic stays at the
int8/int4 byte width (the whole point of the bytes_ratio claim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis, unpack_int4_rows


def _sketch_head_kernel(idx_ref, sketch_ref, *rest, quant=None):
    out_ref = rest[-1]
    idx = idx_ref[...]          # (Bt, L)
    vals = sketch_ref[...]      # (L, R, Vt) f32 | (Lstore, R, Vt) int8
    bt, l = idx.shape

    if quant is not None:
        scale = rest[0][...]    # (L, R) f32
        if quant == "int4":
            vals = unpack_int4_rows(vals, l)      # nibbles → (L, R, Vt) int8
        vals = vals.astype(jnp.float32)
    r, vt = vals.shape[1], vals.shape[2]

    # One-hot over (L, R) flattened: (Bt, L·R) with exactly L nonzeros per
    # row.  Row scales fold into the one-hot (values {0, scale[l, r]}), so
    # each MXU term is exactly scale·q — bitwise the ref dequant product.
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bt, l, r), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32)
    if quant is not None:
        onehot = onehot * scale[None, :, :]
    # MXU: (Bt, L·R) @ (L·R, Vt) — the row-mean over L reads.
    out_ref[...] = jax.lax.dot_general(
        onehot.reshape(bt, l * r), vals.reshape(l * r, vt),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (1.0 / l)


def sketch_head_pallas(
    sketch: jnp.ndarray,     # (L, R, V) f32 | (Lstore, R, V) int8 (quant)
    idx: jnp.ndarray,        # (B, L) int32
    scale: jnp.ndarray | None = None,   # (L, R) f32 when quantized
    *,
    quant: str | None = None,           # None | "int8" | "int4"
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool | None = None,
) -> jnp.ndarray:            # (B, V)
    if interpret is None:
        interpret = interpret_default()
    l = idx.shape[1]
    l_store, r, v = sketch.shape
    n_batch = idx.shape[0]

    idxp = pad_axis(idx, 0, block_b)
    sketchp = pad_axis(sketch, 2, block_v)
    bp, vp = idxp.shape[0], sketchp.shape[2]
    grid = (bp // block_b, vp // block_v)

    in_specs = [
        pl.BlockSpec((block_b, l), lambda i, j: (i, 0)),
        pl.BlockSpec((l_store, r, block_v), lambda i, j: (0, 0, j)),
    ]
    operands = [idxp, sketchp]
    if quant is not None:
        in_specs.append(pl.BlockSpec((l, r), lambda i, j: (0, 0)))
        operands.append(scale)

    out = pl.pallas_call(
        functools.partial(_sketch_head_kernel, quant=quant),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, vp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:n_batch, :v]
