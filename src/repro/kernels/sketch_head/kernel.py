"""Sketched LM-head Pallas kernel — per-class RACE estimate for decode.

This is the framework integration of the paper's technique (DESIGN.md §4):
at decode time the dense d_model×V logit matmul (2·d·V FLOPs/token) is
replaced by an L-row sketch lookup shared across all V classes
(L·V adds/token; L ≪ 2·d).

The class-sharing layout (L, R, V) turns the per-class gather into a single
(1, L·R)·(L·R, Vt) one-hot contraction per vocab tile — an MXU matvec whose
left operand has exactly L nonzeros.  VMEM tiling:

  grid = (B / Bt, V / Vt)
  idx:    (Bt, L)       VMEM
  sketch: (L, R, Vt)    VMEM  — vocab-tiled; with L=64, R=16, Vt=2048 this is
                               64·16·2048·4 B = 8 MB ≤ VMEM; shrink Vt to fit.
  out:    (Bt, Vt)      VMEM
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis


def _sketch_head_kernel(idx_ref, sketch_ref, out_ref):
    idx = idx_ref[...]          # (Bt, L)
    sketch = sketch_ref[...]    # (L, R, Vt)
    l, r, vt = sketch.shape
    bt = idx.shape[0]

    # One-hot over (L, R) flattened: (Bt, L·R) with exactly L ones per row.
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bt, l, r), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32).reshape(bt, l * r)
    flat = sketch.reshape(l * r, vt)
    # MXU: (Bt, L·R) @ (L·R, Vt) — the row-mean over L reads.
    out_ref[...] = jax.lax.dot_general(
        onehot, flat, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (1.0 / l)


def sketch_head_pallas(
    sketch: jnp.ndarray,     # (L, R, V) f32
    idx: jnp.ndarray,        # (B, L) int32
    *,
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool | None = None,
) -> jnp.ndarray:            # (B, V)
    if interpret is None:
        interpret = interpret_default()
    l, r, v = sketch.shape
    n_batch = idx.shape[0]

    idxp = pad_axis(idx, 0, block_b)
    sketchp = pad_axis(sketch, 2, block_v)
    bp, vp = idxp.shape[0], sketchp.shape[2]
    grid = (bp // block_b, vp // block_v)

    out = pl.pallas_call(
        _sketch_head_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i, j: (i, 0)),
            pl.BlockSpec((l, r, block_v), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, vp), jnp.float32),
        interpret=interpret,
    )(idxp, sketchp)
    return out[:n_batch, :v]
