# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Every op package registers its pallas + ref implementations in
# ``repro.kernels.registry``; dispatch is per-call (``backend=``) or global
# (``REPRO_KERNEL_BACKEND`` / ``registry.set_default_backend``) — DESIGN.md §8.
from repro.kernels import registry  # noqa: F401
