"""RACE sketch query Pallas kernel: gather row reads + median-of-means.

TPU mapping (DESIGN.md §3): the whole sketch (C, L, R) stays resident in
VMEM across the batch grid — for the paper's sizes (L≤2000, R≤32, C small)
that's ≤ a few hundred KB, far under the ~16 MB VMEM budget.  The per-row
bucket gather is realized as a one-hot (Bt·L, R) selection contracted on the
MXU instead of a serial dynamic gather (TPU has no efficient scatter/gather
on arbitrary lanes), and MoM runs vectorized on the VPU: group means then a
sorting-network median over the g group axis.

Tiling:
  grid = (B / Bt,)
  idx:    (Bt, L)     VMEM
  sketch: (C, L, R)   VMEM (whole, replicated across grid steps)
  out:    (Bt, C)     VMEM
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis


def _race_query_kernel(idx_ref, sketch_ref, out_ref, *, n_groups: int):
    idx = idx_ref[...]          # (Bt, L) int32
    sketch = sketch_ref[...]    # (C, L, R) f32
    c, l, r = sketch.shape
    bt = idx.shape[0]

    # One-hot selection: (Bt, L, R) vs iota over R.
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bt, l, r), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32)
    # reads[b, c, l] = sum_r sketch[c, l, r] * onehot[b, l, r]
    reads = jnp.einsum("clr,blr->bcl", sketch, onehot)

    # Median of means over L rows in g groups (vectorized).
    m = l // n_groups
    grouped = reads[..., : n_groups * m].reshape(bt, c, n_groups, m)
    means = jnp.mean(grouped, axis=-1)          # (Bt, C, g)
    med = jnp.median(means, axis=-1)            # (Bt, C)
    out_ref[...] = med


def race_query_pallas(
    sketch: jnp.ndarray,     # (C, L, R) f32
    idx: jnp.ndarray,        # (B, L) int32
    *,
    n_groups: int,
    block_b: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:            # (B, C)
    if interpret is None:
        interpret = interpret_default()
    n_batch, n_rows = idx.shape
    c, l, r = sketch.shape
    assert l == n_rows

    idxp = pad_axis(idx, 0, block_b)
    bp = idxp.shape[0]
    grid = (bp // block_b,)

    out = pl.pallas_call(
        functools.partial(_race_query_kernel, n_groups=n_groups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((c, l, r), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), jnp.float32),
        interpret=interpret,
    )(idxp, sketch)
    return out[:n_batch]
