"""Public wrapper for the RACE query kernel (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.race_query.kernel import race_query_pallas
from repro.kernels.race_query.ref import race_query_ref


@registry.register("race_query", "pallas")
@partial(jax.jit, static_argnames=("n_groups", "block_b"))
def _pallas(sketch, idx, *, n_groups, block_b):
    return race_query_pallas(sketch, idx, n_groups=n_groups, block_b=block_b)


@registry.register("race_query", "ref")
@partial(jax.jit, static_argnames=("n_groups", "block_b"))
def _ref(sketch, idx, *, n_groups, block_b):
    del block_b  # tiling is a pallas concern
    return race_query_ref(sketch, idx, n_groups)


def race_query(
    sketch: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    n_groups: int,
    block_b: int = 128,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Median-of-means sketch estimate (B, C) from bucket indices (B, L)."""
    impl = registry.resolve("race_query", backend, use_pallas)
    return impl(sketch, idx, n_groups=n_groups, block_b=block_b)
