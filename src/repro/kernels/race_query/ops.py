"""Jit'd public wrapper for the RACE query kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.race_query.kernel import race_query_pallas
from repro.kernels.race_query.ref import race_query_ref


@partial(jax.jit, static_argnames=("n_groups", "block_b", "use_pallas"))
def race_query(
    sketch: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    n_groups: int,
    block_b: int = 128,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Median-of-means sketch estimate (B, C) from bucket indices (B, L)."""
    if use_pallas:
        return race_query_pallas(sketch, idx, n_groups=n_groups, block_b=block_b)
    return race_query_ref(sketch, idx, n_groups)
