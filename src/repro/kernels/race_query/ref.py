"""Pure-jnp oracle for the RACE sketch query kernel (Algorithm 2).

Given precomputed bucket indices, gathers the L row reads per output channel
and reduces with median-of-means.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.sketch import mom_estimate


def race_query_ref(
    sketch: jnp.ndarray,   # (C, L, R) f32
    idx: jnp.ndarray,      # (B, L) int32
    n_groups: int,
) -> jnp.ndarray:          # (B, C)
    reads = jnp.take_along_axis(
        sketch[None],             # (1, C, L, R)
        idx[:, None, :, None],    # (B, 1, L, 1)
        axis=-1,
    )[..., 0]                     # (B, C, L)
    return mom_estimate(reads, n_groups)
