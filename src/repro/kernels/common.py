"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU via ``interpret=True`` — the kernel body runs in Python with
identical semantics.  ``interpret_default()`` flips automatically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Interpret kernels on any non-TPU backend (this container is CPU)."""
    return jax.default_backend() != "tpu"


def mesh_axis_size(mesh, name: str) -> int:
    """Size of mesh axis ``name``; 1 when ``mesh`` is None or lacks the axis."""
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def pad_axis(x: jnp.ndarray, axis: int, multiple: int, value=0) -> jnp.ndarray:
    """Pad ``axis`` of ``x`` up to the next multiple (TPU tile alignment)."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)
