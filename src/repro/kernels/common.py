"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU via ``interpret=True`` — the kernel body runs in Python with
identical semantics.  ``interpret_default()`` flips automatically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Interpret kernels on any non-TPU backend (this container is CPU)."""
    return jax.default_backend() != "tpu"


def mesh_axis_size(mesh, name: str) -> int:
    """Size of mesh axis ``name``; 1 when ``mesh`` is None or lacks the axis."""
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def pad_axis(x: jnp.ndarray, axis: int, multiple: int, value=0) -> jnp.ndarray:
    """Pad ``axis`` of ``x`` up to the next multiple (TPU tile alignment)."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def select_tenant_rows(per_tenant: jnp.ndarray,
                       tenant_ids: jnp.ndarray) -> jnp.ndarray:
    """Per-slot tenant gather: ``out[b] = per_tenant[tenant_ids[b], b]``.

    ``per_tenant`` is a (T, B, …) stack of full-batch outputs, one per
    resident tenant, each computed by the *unmodified* single-tenant code
    path; ``tenant_ids`` is the (B,) int32 slot→tenant binding.  The gather
    is arithmetic-free (``take_along_axis`` moves bits, it never re-reduces),
    so row ``b`` of the result is bitwise identical to running tenant
    ``tenant_ids[b]``'s head alone — the per-slot head binding costs no
    parity (DESIGN.md §14).
    """
    idx = tenant_ids.reshape((1, -1) + (1,) * (per_tenant.ndim - 2))
    idx = idx.astype(jnp.int32)
    return jnp.take_along_axis(per_tenant, idx, axis=0)[0]


def pack_int4_rows(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int4-valued int8 rows pairwise along axis 0: (N, …) → (⌈N/2⌉, …).

    Byte ``i`` holds row ``2i`` in its low nibble and row ``2i+1`` in its
    high nibble (odd N gets a zero pad row).  Packing along the *leading*
    axis — not the trailing lane axis — keeps the minor (V) dimension of the
    sketch count arrays intact, so the quantized decode kernels tile V
    exactly like the f32 kernels and the true row count is always
    recoverable from the (B, L) index / (L, K, d') hash-bank shapes (no
    ambiguity at odd V; DESIGN.md §12).

    Args:
      q: int8 array with values in [-8, 7]; axis 0 is the packed axis.

    Returns:
      int8 array of packed bytes, shape ``(⌈N/2⌉, …)``.
    """
    if q.shape[0] % 2:
        q = pad_axis(q, 0, 2)
    lo = q[0::2].astype(jnp.uint8) & jnp.uint8(0x0F)
    hi = q[1::2].astype(jnp.uint8) & jnp.uint8(0x0F)
    return jax.lax.bitcast_convert_type(
        lo | (hi << jnp.uint8(4)), jnp.int8)


def unpack_int4_rows(packed: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Inverse of :func:`pack_int4_rows`: (⌈N/2⌉, …) bytes → (n_rows, …) int8.

    Sign-extends each nibble ((x << 4) >> 4 arithmetic-shift trick, all in
    int8 registers) and interleaves low/high back to row order; ``n_rows``
    slices off the pad row of an odd-N pack.  Cheap enough to run inside a
    kernel body per tile — the dequantized values never touch HBM.
    """
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    rows = jnp.stack([lo, hi], axis=1).reshape(-1, *packed.shape[1:])
    return rows[:n_rows]
