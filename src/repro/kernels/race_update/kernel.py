"""Sketch-construction Pallas kernel (Algorithm 1) — scatter-add as matmul.

TPU has no efficient atomic scatter; the weighted increments
``S[l, h_l(x_i)] += α_i`` are instead realized as a dense contraction
(DESIGN.md §3): a one-hot cube over the bucket axis contracted against the
weight matrix on the MXU, accumulated across grid steps over the point axis.

Tiling:
  grid = (M / Mt,)                         — points are streamed
  idx:    (Mt, L)     VMEM
  alphas: (Mt, C)     VMEM
  out:    (C, L, R)   VMEM, accumulated in place across grid iterations
          (output block index is constant, so Pallas keeps it resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis


def _race_update_kernel(idx_ref, alpha_ref, out_ref, *, n_buckets: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]        # (Mt, L)
    alphas = alpha_ref[...]   # (Mt, C)
    mt, l = idx.shape

    iota_r = jax.lax.broadcasted_iota(jnp.int32, (mt, l, n_buckets), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32)    # (Mt, L, R)
    # (C, L, R) += alphas^T ⊗ onehot, contracted over the point axis on MXU.
    delta = jnp.einsum("mc,mlr->clr", alphas, onehot)
    out_ref[...] += delta


def race_update_pallas(
    idx: jnp.ndarray,        # (M, L) int32
    alphas: jnp.ndarray,     # (M, C) f32
    *,
    n_buckets: int,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:            # (C, L, R) — the delta to add to an existing sketch
    if interpret is None:
        interpret = interpret_default()
    m, l = idx.shape
    c = alphas.shape[1]

    # Pad points with zero-weight entries (harmless: they add 0 everywhere).
    idxp = pad_axis(idx, 0, block_m)
    alphap = pad_axis(alphas.astype(jnp.float32), 0, block_m)
    mp = idxp.shape[0]
    grid = (mp // block_m,)

    return pl.pallas_call(
        functools.partial(_race_update_kernel, n_buckets=n_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, l), lambda i: (i, 0)),
            pl.BlockSpec((block_m, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, l, n_buckets), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, l, n_buckets), jnp.float32),
        interpret=interpret,
    )(idxp, alphap)
