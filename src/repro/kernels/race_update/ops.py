"""Public wrapper for the sketch-construction kernel (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.race_update.kernel import race_update_pallas
from repro.kernels.race_update.ref import race_update_ref


@registry.register("race_update", "pallas")
@partial(jax.jit, static_argnames=("block_m",))
def _pallas(sketch, idx, alphas, *, block_m):
    delta = race_update_pallas(idx, alphas, n_buckets=sketch.shape[-1],
                               block_m=block_m)
    return sketch + delta


@registry.register("race_update", "ref")
@partial(jax.jit, static_argnames=("block_m",))
def _ref(sketch, idx, alphas, *, block_m):
    del block_m  # tiling is a pallas concern
    return race_update_ref(sketch, idx, alphas)


def race_update(
    sketch: jnp.ndarray,   # (C, L, R)
    idx: jnp.ndarray,      # (M, L)
    alphas: jnp.ndarray,   # (M, C)
    *,
    block_m: int = 256,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Accumulate weighted points into the sketch; returns the new sketch."""
    impl = registry.resolve("race_update", backend, use_pallas)
    return impl(sketch, idx, alphas, block_m=block_m)
