"""Jit'd public wrapper for the sketch-construction kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.race_update.kernel import race_update_pallas
from repro.kernels.race_update.ref import race_update_ref


@partial(jax.jit, static_argnames=("block_m", "use_pallas"))
def race_update(
    sketch: jnp.ndarray,   # (C, L, R)
    idx: jnp.ndarray,      # (M, L)
    alphas: jnp.ndarray,   # (M, C)
    *,
    block_m: int = 256,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Accumulate weighted points into the sketch; returns the new sketch."""
    if use_pallas:
        delta = race_update_pallas(
            idx, alphas, n_buckets=sketch.shape[-1], block_m=block_m
        )
        return sketch + delta
    return race_update_ref(sketch, idx, alphas)
