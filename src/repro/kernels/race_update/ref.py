"""Pure-jnp oracle for the sketch-construction kernel (Algorithm 1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def race_update_ref(
    sketch: jnp.ndarray,   # (C, L, R) f32 — existing sketch to accumulate into
    idx: jnp.ndarray,      # (M, L) int32  — bucket index of each point per row
    alphas: jnp.ndarray,   # (M, C) f32    — per-point weights
) -> jnp.ndarray:          # (C, L, R)
    n_buckets = sketch.shape[-1]
    onehot = jax.nn.one_hot(idx, n_buckets, dtype=jnp.float32)  # (M, L, R)
    return sketch + jnp.einsum("mc,mlr->clr", alphas.astype(jnp.float32), onehot)
