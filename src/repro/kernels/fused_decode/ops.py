"""Public wrapper for the fused sketched-decode kernel (registry-dispatched).

``mesh=`` enables the sharded decode path (DESIGN.md §9): hash params and
count arrays are partitioned over the mesh's ``model`` axis on the
repetition axis L, each shard runs the whole fused kernel (transform →
hash → gather) on its local L/m repetitions, and the per-shard partial
means finish with a single ``psum`` of the (B, V) logits — one collective
per decode step.  Falls back to the single-device path when L does not
divide the ``model`` axis size.

Quantized storage (``quant="int8"|"int4"``, DESIGN.md §12) threads the
(L, R) f32 ``scale`` alongside the integer count array; under the mesh the
scales partition with their rows (``P("model", None)``).  int4 packs two
L-rows per byte on axis 0, so the sharded path additionally requires shard
boundaries on byte boundaries (L/msize even) and falls back otherwise.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.kernels.common import mesh_axis_size, select_tenant_rows
from repro.kernels.fused_decode.kernel import fused_decode_pallas
from repro.kernels.fused_decode.ref import fused_decode_ref


@registry.register("fused_decode", "pallas")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "quant",
                                   "block_b", "block_v"))
def _pallas(hidden, proj, w, b, sketch, scale=None, *, bandwidth, n_buckets,
            quant=None, block_b, block_v, row_salt=None):
    return fused_decode_pallas(hidden, proj, w, b, sketch,
                               bandwidth=bandwidth, n_buckets=n_buckets,
                               scale=scale, quant=quant,
                               block_b=block_b, block_v=block_v,
                               row_salt=row_salt)


@registry.register("fused_decode", "ref")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "quant",
                                   "block_b", "block_v"))
def _ref(hidden, proj, w, b, sketch, scale=None, *, bandwidth, n_buckets,
         quant=None, block_b, block_v, row_salt=None):
    del block_b, block_v  # tiling is a pallas concern
    return fused_decode_ref(hidden, proj, w, b, sketch, bandwidth, n_buckets,
                            row_salt=row_salt, scale=scale, quant=quant)


def fused_decode_logits(
    hidden: jnp.ndarray,     # (B, d_model) — final backbone hiddens
    proj: jnp.ndarray,       # (d_model, d') asymmetric transform A
    w: jnp.ndarray,          # (L, K, d') hash projections
    b: jnp.ndarray,          # (L, K) hash offsets
    sketch: jnp.ndarray,     # (L, R, V) f32 | (Lstore, R, V) int8 when quant
    *,
    bandwidth: float,
    n_buckets: int,
    scale: Optional[jnp.ndarray] = None,   # (L, R) f32 when quantized
    quant: Optional[str] = None,           # None | "int8" | "int4"
    block_b: int = 8,
    block_v: int = 2048,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
    mesh=None,
    tenant_ids: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Sketched (B, V) logits in one kernel: transform → hash → gather.

    Args:
      hidden: (B, d_model) final backbone hidden states.
      proj: (d_model, d') asymmetric transform.
      w / b: (L, K, d') / (L, K) p-stable hash bank.
      sketch: (L, R, V) per-class RACE count arrays (int8 carrier under
        ``quant``: (L, R, V) int8 or (⌈L/2⌉, R, V) packed int4 bytes).
      bandwidth / n_buckets: static LSH family parameters.
      scale: (L, R) f32 per-row dequantization scales (required iff
        ``quant`` is set).
      quant: ``None`` (f32 counts), ``"int8"`` or ``"int4"`` — static.
      block_b / block_v: pallas VMEM tile sizes.
      use_pallas: deprecated pallas/ref switch (prefer ``backend``).
      backend: kernel registry backend (``"pallas"`` / ``"ref"``); ``None``
        resolves through the registry default.
      mesh: a ``jax.sharding.Mesh`` with a ``model`` axis to run the
        row-sharded psum path; ``None`` (default) is the single-device path.
      tenant_ids: (B,) int32 per-slot tenant indices for the multi-tenant
        path (DESIGN.md §14).  When set, every head operand carries a
        leading tenant axis T — proj (T, d, d'), w (T, L, K, d'),
        b (T, L, K), sketch (T, L, R, V), scale (T, L, R) — each resident
        tenant's logits are computed over the full batch by this *same*
        single-tenant path (shard_map psum included), and row ``b`` is
        selected from tenant ``tenant_ids[b]``'s stack arithmetic-free, so
        per-slot heads cost no bitwise parity.

    Returns:
      (B, V) f32 logit estimates.
    """
    if (scale is None) != (quant is None):
        raise ValueError("quant and scale must be passed together "
                         f"(quant={quant!r}, scale is "
                         f"{'None' if scale is None else 'set'})")
    if tenant_ids is not None:
        per_tenant = jnp.stack([
            fused_decode_logits(
                hidden, proj[t], w[t], b[t], sketch[t],
                bandwidth=bandwidth, n_buckets=n_buckets,
                scale=None if scale is None else scale[t], quant=quant,
                block_b=block_b, block_v=block_v, use_pallas=use_pallas,
                backend=backend, mesh=mesh)
            for t in range(w.shape[0])])
        return select_tenant_rows(per_tenant, tenant_ids)
    impl = registry.resolve("fused_decode", backend, use_pallas)
    kw = dict(bandwidth=bandwidth, n_buckets=n_buckets, quant=quant,
              block_b=block_b, block_v=block_v)
    l = w.shape[0]               # true repetition count (storage may pack)
    l_store = sketch.shape[0]
    msize = mesh_axis_size(mesh, "model")
    shardable = msize > 1 and l % msize == 0 and l_store % msize == 0
    if quant == "int4":
        # Byte-aligned shards only: no pad row, even true rows per shard.
        shardable = shardable and 2 * l_store == l
    if shardable:
        l_shard = l // msize
        # Keep the batch sharded over data when it divides (decode caches
        # already are): each device transforms/hashes only its rows and the
        # psum moves (B/d, V), not (B, V).
        dsize = mesh_axis_size(mesh, "data")
        bspec = "data" if dsize > 1 and hidden.shape[0] % dsize == 0 else None

        def local(h, pj, ws, bs, sk, *sc):
            # The hash fold is salted by the *global* row index; a shard
            # holding rows [i·L/m, (i+1)·L/m) must hash with those salts.
            from repro.core.lsh import row_salts
            start = jax.lax.axis_index("model") * l_shard
            part = impl(h, pj, ws, bs, sk, *sc,
                        row_salt=row_salts(l_shard, start), **kw)
            return jax.lax.psum(part * (l_shard / l), "model")

        in_specs = [P(bspec, None), P(None, None), P("model", None, None),
                    P("model", None), P("model", None, None)]
        operands = [hidden, proj, w, b, sketch]
        if quant is not None:
            in_specs.append(P("model", None))
            operands.append(scale)

        # check_rep=False: pallas_call has no replication rule; the psum
        # makes the output replicated over model by construction.
        return shard_map(
            local, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=P(bspec, None), check_rep=False)(*operands)
    return impl(hidden, proj, w, b, sketch, scale, **kw)
