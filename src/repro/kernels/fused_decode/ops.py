"""Public wrapper for the fused sketched-decode kernel (registry-dispatched)."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.fused_decode.kernel import fused_decode_pallas
from repro.kernels.fused_decode.ref import fused_decode_ref


@registry.register("fused_decode", "pallas")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "block_b",
                                   "block_v"))
def _pallas(hidden, proj, w, b, sketch, *, bandwidth, n_buckets, block_b,
            block_v):
    return fused_decode_pallas(hidden, proj, w, b, sketch,
                               bandwidth=bandwidth, n_buckets=n_buckets,
                               block_b=block_b, block_v=block_v)


@registry.register("fused_decode", "ref")
@partial(jax.jit, static_argnames=("bandwidth", "n_buckets", "block_b",
                                   "block_v"))
def _ref(hidden, proj, w, b, sketch, *, bandwidth, n_buckets, block_b,
         block_v):
    del block_b, block_v  # tiling is a pallas concern
    return fused_decode_ref(hidden, proj, w, b, sketch, bandwidth, n_buckets)


def fused_decode_logits(
    hidden: jnp.ndarray,     # (B, d_model) — final backbone hiddens
    proj: jnp.ndarray,       # (d_model, d') asymmetric transform A
    w: jnp.ndarray,          # (L, K, d') hash projections
    b: jnp.ndarray,          # (L, K) hash offsets
    sketch: jnp.ndarray,     # (L, R, V) per-class arrays
    *,
    bandwidth: float,
    n_buckets: int,
    block_b: int = 8,
    block_v: int = 2048,
    use_pallas: Optional[bool] = None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """Sketched (B, V) logits in one kernel: transform → hash → gather."""
    impl = registry.resolve("fused_decode", backend, use_pallas)
    return impl(hidden, proj, w, b, sketch, bandwidth=bandwidth,
                n_buckets=n_buckets, block_b=block_b, block_v=block_v)
