"""Fused sketched-decode Pallas kernel: projection → hash → sketch gather.

The two-kernel decode path (repro.kernels.lsh_hash then
repro.kernels.sketch_head) materializes the ``(B, L)`` int32 bucket-index
tensor in HBM between the calls; at serving batch sizes that round trip —
write + re-read of B·L·4 bytes plus a kernel-launch boundary — is pure
overhead on a path that is otherwise a handful of tiny matmuls.  This kernel
fuses the whole sketched head (DESIGN.md §4) into a single ``pallas_call``:

  1. asymmetric transform   q = h · A            (MXU, (Bt, d)·(d, d'))
  2. p-stable hash          proj = q · Wᵀ + b    (MXU, (Bt, d')·(d', L·K))
                            idx  = mix(floor(proj / r))        (VPU)
  3. shared-index gather    logits = onehot(idx) · S / L       (MXU)

Tiling (DESIGN.md §3):

  grid = (B / Bt, V / Vt)
  h:      (Bt, d)       VMEM
  A:      (d, d')       VMEM  (whole transform resident)
  w:      (L·K, d')     VMEM  (whole hash bank resident)
  b:      (1, L·K)      VMEM
  sketch: (L, R, Vt)    VMEM  — vocab-tiled exactly like sketch_head
  out:    (Bt, Vt)      VMEM

Steps 1–2 are recomputed per vocab tile: they cost Bt·d·d' + Bt·d'·L·K
MXU FLOPs — orders of magnitude below the step-3 gather contraction — and
recomputation is what lets the index tensor live entirely in registers/VMEM
instead of HBM.  Bit-exact index parity with the two-kernel path is asserted
in tests (same Carter–Wegman mix, same golden-ratio row salt).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_default, pad_axis
from repro.kernels.lsh_hash.kernel import _mix_codes


def _fused_decode_kernel(h_ref, a_ref, w_ref, b_ref, salt_ref, sketch_ref,
                         out_ref, *, k: int, n_buckets: int, bandwidth: float,
                         n_rows: int):
    h = h_ref[...]                        # (Bt, d)
    a = a_ref[...]                        # (d, d')
    w = w_ref[...]                        # (L*K, d')
    b = b_ref[...]                        # (1, L*K)
    salt = salt_ref[...][0]               # (L,) uint32 global-row fold salts
    sketch = sketch_ref[...]              # (L, R, Vt)
    l, r, vt = sketch.shape
    bt = h.shape[0]

    # 1. asymmetric transform (MXU).
    q = jax.lax.dot_general(
        h, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # (Bt, d')
    # 2. hash projection (MXU) + quantize + K-fold rehash (VPU).
    proj = jax.lax.dot_general(
        q, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # (Bt, L*K)
    codes = jnp.floor((proj + b) / bandwidth).astype(jnp.int32).astype(jnp.uint32)
    codes = codes.reshape(bt, n_rows, k)
    idx = _mix_codes(codes, k, n_buckets, salt=salt)  # (Bt, L)

    # 3. shared-index gather as a one-hot MXU contraction (row-mean over L).
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bt, l, r), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32).reshape(bt, l * r)
    flat = sketch.reshape(l * r, vt)
    out_ref[...] = jax.lax.dot_general(
        onehot, flat, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (1.0 / l)


def fused_decode_pallas(
    hidden: jnp.ndarray,     # (B, d) f32 — final backbone hiddens
    proj: jnp.ndarray,       # (d, d') f32 — asymmetric transform A
    w: jnp.ndarray,          # (L, K, d') f32 — hash bank
    b: jnp.ndarray,          # (L, K) f32 — hash offsets
    sketch: jnp.ndarray,     # (L, R, V) f32 — per-class RACE arrays
    *,
    bandwidth: float,
    n_buckets: int,
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool | None = None,
    row_salt: jnp.ndarray | None = None,   # (L,) uint32 global-row fold salts
) -> jnp.ndarray:            # (B, V) f32 logits
    if interpret is None:
        interpret = interpret_default()
    n_batch, d = hidden.shape
    d_proj = proj.shape[1]
    n_rows, k, _ = w.shape
    l, r, v = sketch.shape

    w2 = w.reshape(n_rows * k, d_proj)
    b2 = b.reshape(1, n_rows * k)
    if row_salt is None:
        from repro.core.lsh import row_salts
        row_salt = row_salts(n_rows)
    salt2 = row_salt.reshape(1, n_rows)

    hp = pad_axis(hidden, 0, block_b)
    sketchp = pad_axis(sketch, 2, block_v)
    bp, vp = hp.shape[0], sketchp.shape[2]
    grid = (bp // block_b, vp // block_v)

    out = pl.pallas_call(
        functools.partial(
            _fused_decode_kernel, k=k, n_buckets=n_buckets,
            bandwidth=bandwidth, n_rows=n_rows,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, d_proj), lambda i, j: (0, 0)),
            pl.BlockSpec((n_rows * k, d_proj), lambda i, j: (0, 0)),
            pl.BlockSpec((1, n_rows * k), lambda i, j: (0, 0)),
            pl.BlockSpec((1, n_rows), lambda i, j: (0, 0)),
            pl.BlockSpec((l, r, block_v), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, vp), jnp.float32),
        interpret=interpret,
    )(hp, proj, w2, b2, salt2, sketchp)
    return out[:n_batch, :v]
