"""Fused sketched-decode Pallas kernel: projection → hash → sketch gather.

The two-kernel decode path (repro.kernels.lsh_hash then
repro.kernels.sketch_head) materializes the ``(B, L)`` int32 bucket-index
tensor in HBM between the calls; at serving batch sizes that round trip —
write + re-read of B·L·4 bytes plus a kernel-launch boundary — is pure
overhead on a path that is otherwise a handful of tiny matmuls.  This kernel
fuses the whole sketched head (DESIGN.md §4) into a single ``pallas_call``:

  1. asymmetric transform   q = h · A            (MXU, (Bt, d)·(d, d'))
  2. p-stable hash          proj = q · Wᵀ + b    (MXU, (Bt, d')·(d', L·K))
                            idx  = mix(floor(proj / r))        (VPU)
  3. shared-index gather    logits = onehot(idx) · S / L       (MXU)

Tiling (DESIGN.md §3):

  grid = (B / Bt, V / Vt)
  h:      (Bt, d)       VMEM
  A:      (d, d')       VMEM  (whole transform resident)
  w:      (L·K, d')     VMEM  (whole hash bank resident)
  b:      (1, L·K)      VMEM
  sketch: (L, R, Vt)    VMEM  — vocab-tiled exactly like sketch_head
  out:    (Bt, Vt)      VMEM

Steps 1–2 are recomputed per vocab tile: they cost Bt·d·d' + Bt·d'·L·K
MXU FLOPs — orders of magnitude below the step-3 gather contraction — and
recomputation is what lets the index tensor live entirely in registers/VMEM
instead of HBM.  Bit-exact index parity with the two-kernel path is asserted
in tests (same Carter–Wegman mix, same golden-ratio row salt).

Quantized storage (``quant``, DESIGN.md §12): HBM holds the sketch as int8
or packed int4 (two L-rows per byte on axis 0) plus (L, R) f32 scales; the
step-3 gather folds the scales into the one-hot left operand so dequantized
f32 counts exist only as MXU operands, never in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (interpret_default, pad_axis,
                                  unpack_int4_rows)
from repro.kernels.lsh_hash.kernel import _mix_codes


def _fused_decode_kernel(h_ref, a_ref, w_ref, b_ref, salt_ref, sketch_ref,
                         *rest, k: int, n_buckets: int, bandwidth: float,
                         n_rows: int, quant: str | None = None):
    out_ref = rest[-1]
    # Cast up front so bf16 hiddens follow the oracle's f32 arithmetic.
    h = h_ref[...].astype(jnp.float32)    # (Bt, d)
    a = a_ref[...]                        # (d, d')
    w = w_ref[...]                        # (L*K, d')
    b = b_ref[...]                        # (1, L*K)
    salt = salt_ref[...][0]               # (L,) uint32 global-row fold salts
    vals = sketch_ref[...]                # (L, R, Vt) f32 | (Lstore, R, Vt) i8
    bt = h.shape[0]
    l = n_rows

    # 1. asymmetric transform (MXU).
    q = jax.lax.dot_general(
        h, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # (Bt, d')
    # 2. hash projection (MXU) + quantize + K-fold rehash (VPU).
    proj = jax.lax.dot_general(
        q, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                     # (Bt, L*K)
    codes = jnp.floor((proj + b) / bandwidth).astype(jnp.int32).astype(jnp.uint32)
    codes = codes.reshape(bt, n_rows, k)
    idx = _mix_codes(codes, k, n_buckets, salt=salt)  # (Bt, L)

    # 3. shared-index gather as a one-hot MXU contraction (row-mean over L).
    if quant is not None:
        scale = rest[0][...]              # (L, R) f32
        if quant == "int4":
            vals = unpack_int4_rows(vals, l)
        vals = vals.astype(jnp.float32)
    r, vt = vals.shape[1], vals.shape[2]
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bt, l, r), 2)
    onehot = (iota_r == idx[:, :, None]).astype(jnp.float32)
    if quant is not None:
        # Row scales fold into the one-hot: each MXU term is exactly
        # scale·q, term-wise equal to the ref dequant product.
        onehot = onehot * scale[None, :, :]
    out_ref[...] = jax.lax.dot_general(
        onehot.reshape(bt, l * r), vals.reshape(l * r, vt),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (1.0 / l)


def fused_decode_pallas(
    hidden: jnp.ndarray,     # (B, d) f32/bf16 — final backbone hiddens
    proj: jnp.ndarray,       # (d, d') f32 — asymmetric transform A
    w: jnp.ndarray,          # (L, K, d') f32 — hash bank
    b: jnp.ndarray,          # (L, K) f32 — hash offsets
    sketch: jnp.ndarray,     # (L, R, V) f32 | (Lstore, R, V) int8 (quant)
    *,
    bandwidth: float,
    n_buckets: int,
    scale: jnp.ndarray | None = None,      # (L, R) f32 when quantized
    quant: str | None = None,              # None | "int8" | "int4"
    block_b: int = 8,
    block_v: int = 2048,
    interpret: bool | None = None,
    row_salt: jnp.ndarray | None = None,   # (L,) uint32 global-row fold salts
) -> jnp.ndarray:            # (B, V) f32 logits
    if interpret is None:
        interpret = interpret_default()
    n_batch, d = hidden.shape
    d_proj = proj.shape[1]
    n_rows, k, _ = w.shape
    l_store, r, v = sketch.shape

    w2 = w.reshape(n_rows * k, d_proj)
    b2 = b.reshape(1, n_rows * k)
    if row_salt is None:
        from repro.core.lsh import row_salts
        row_salt = row_salts(n_rows)
    salt2 = row_salt.reshape(1, n_rows)

    hp = pad_axis(hidden, 0, block_b)
    sketchp = pad_axis(sketch, 2, block_v)
    bp, vp = hp.shape[0], sketchp.shape[2]
    grid = (bp // block_b, vp // block_v)

    in_specs = [
        pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
        pl.BlockSpec((d, d_proj), lambda i, j: (0, 0)),
        pl.BlockSpec((n_rows * k, d_proj), lambda i, j: (0, 0)),
        pl.BlockSpec((1, n_rows * k), lambda i, j: (0, 0)),
        pl.BlockSpec((1, n_rows), lambda i, j: (0, 0)),
        pl.BlockSpec((l_store, r, block_v), lambda i, j: (0, 0, j)),
    ]
    operands = [hp, proj, w2, b2, salt2, sketchp]
    if quant is not None:
        in_specs.append(pl.BlockSpec((n_rows, r), lambda i, j: (0, 0)))
        operands.append(scale)

    out = pl.pallas_call(
        functools.partial(
            _fused_decode_kernel, k=k, n_buckets=n_buckets,
            bandwidth=bandwidth, n_rows=n_rows, quant=quant,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, vp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:n_batch, :v]
