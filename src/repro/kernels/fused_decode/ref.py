"""Pure-jnp oracle for the fused sketched-decode kernel.

Composes the two existing oracles around the asymmetric transform:

    q      = hidden @ proj                      # (B, d')
    idx    = lsh_hash_ref(q, w, b)              # (B, L)
    logits = sketch_head_ref(sketch, idx)       # (B, V)

The fused kernel must match this composition exactly on the indices (same
integer mix) and within float tolerance on the logits.  Quantized storage
passes ``scale``/``quant`` straight through to the sketch-head oracle,
which materializes the dequantized f32 array (oracle only — the kernel
keeps dequant in-register, DESIGN.md §12).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lsh_hash.ref import lsh_hash_ref
from repro.kernels.sketch_head.ref import sketch_head_ref


def fused_decode_ref(
    hidden: jnp.ndarray,     # (B, d) f32/bf16
    proj: jnp.ndarray,       # (d, d') f32
    w: jnp.ndarray,          # (L, K, d') f32
    b: jnp.ndarray,          # (L, K) f32
    sketch: jnp.ndarray,     # (L, R, V) f32 | (Lstore, R, V) int8 (quant)
    bandwidth: float,
    n_buckets: int,
    row_salt: jnp.ndarray | None = None,   # (L,) uint32 global-row fold salts
    scale: jnp.ndarray | None = None,      # (L, R) f32 when quantized
    quant: str | None = None,              # None | "int8" | "int4"
) -> jnp.ndarray:            # (B, V)
    q = hidden.astype(jnp.float32) @ proj
    idx = lsh_hash_ref(q, w, b, bandwidth, n_buckets, row_salt=row_salt)
    return sketch_head_ref(sketch, idx, scale, quant)
