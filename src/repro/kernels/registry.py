"""Kernel backend registry: one dispatch point for every op package.

Each op package (``fused_decode``, ``lsh_hash``, ``sketch_head``,
``race_query``, ``race_update``, ``flash_attn``) registers its
implementations here under a backend name:

* ``"pallas"`` — the ``pl.pallas_call`` kernel (interpret mode off-TPU), and
* ``"ref"``    — the pure-jnp oracle from the package's ``ref.py``.

Dispatch is resolved per call (``backend="ref"`` on any op wrapper) or
globally: ``set_default_backend("ref")`` in-process, or the
``REPRO_KERNEL_BACKEND`` environment variable — which makes CPU/CI runs and
parity sweeps a config switch instead of new code (DESIGN.md §8).

Resolution order per call:

1. explicit ``backend=`` argument,
2. legacy ``use_pallas=`` argument (True → ``pallas``, False → ``ref``),
3. ``set_default_backend(...)`` override,
4. ``REPRO_KERNEL_BACKEND`` environment variable,
5. the registry default, ``"pallas"``.

Note that op wrappers are jitted with the backend as a static argument; the
environment variable is read when a call first traces, so flip it before the
first call (as the CI ref-dispatch job does), not mid-run.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional

ENV_VAR = "REPRO_KERNEL_BACKEND"
DEFAULT_BACKEND = "pallas"

_IMPLS: Dict[str, Dict[str, Callable]] = {}
_OVERRIDE: Optional[str] = None


def register(op: str, backend: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``backend`` implementation of ``op``."""

    def deco(fn: Callable) -> Callable:
        _IMPLS.setdefault(op, {})[backend] = fn
        return fn

    return deco


def ops() -> List[str]:
    """Registered op names (packages that have imported their ops module)."""
    return sorted(_IMPLS)


def backends(op: str) -> List[str]:
    """Backend names registered for ``op``."""
    if op not in _IMPLS:
        raise KeyError(f"unknown kernel op {op!r}; registered: {ops()}")
    return sorted(_IMPLS[op])


def set_default_backend(backend: Optional[str]) -> None:
    """Set (or clear, with None) the process-wide backend override.

    Takes precedence over ``REPRO_KERNEL_BACKEND``; only affects calls that
    have not already traced with another backend.
    """
    global _OVERRIDE
    if backend is not None and backend not in ("pallas", "ref"):
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected 'pallas' or 'ref'")
    _OVERRIDE = backend


def default_backend() -> str:
    """The backend used when a call does not pick one explicitly."""
    return _OVERRIDE or os.environ.get(ENV_VAR) or DEFAULT_BACKEND


def resolve(op: str, backend: Optional[str] = None,
            use_pallas: Optional[bool] = None) -> Callable:
    """Pick the implementation of ``op`` for this call (see module docstring)."""
    if backend is None and use_pallas is not None:
        backend = "pallas" if use_pallas else "ref"
    if backend is None:
        backend = default_backend()
    impls = _IMPLS.get(op)
    if impls is None:
        raise KeyError(f"unknown kernel op {op!r}; registered: {ops()}")
    if backend not in impls:
        raise ValueError(
            f"kernel op {op!r} has no backend {backend!r}; "
            f"registered: {sorted(impls)}")
    return impls[backend]
