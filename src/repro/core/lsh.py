"""Locality-sensitive hash families used by Representer Sketch.

Implements the three LSH families referenced by the paper:

* :class:`L2LSH` — p-stable (Gaussian) Euclidean LSH of Datar et al. [44].
  ``h(x) = floor((w·x + b) / r)`` with ``w ~ N(0, I)``, ``b ~ U[0, r)``.
  Its collision probability is the (shift-invariant, *universal*) L2-LSH
  kernel of Lemma 2.
* :class:`SRPLSH` — sign random projections for angular similarity.
* :class:`AchlioptasL2LSH` — the database-friendly variant the paper uses at
  inference time: projection entries are ``sqrt(3)·{−1, 0, +1}`` with
  probabilities ``{1/6, 2/3, 1/6}`` so hashing costs only adds/subs on edge
  hardware.  On TPU we keep the same distribution but materialize it dense so
  the projection runs on the MXU (see DESIGN.md §3).

Every family exposes:

* ``params(key, d)`` — pytree of hash parameters for ``L`` rows × ``K``
  concatenated hashes.
* ``hash(params, x)`` — ``(..., L)`` int32 row indices in ``[0, R)`` for a
  batch of points, with the K sub-hashes combined into one index by a
  universal rehash (the "suitable transformation to Z" of §3.4).
* ``collision_probability(dist)`` — the LSH kernel ``K(x, y)`` as a function
  of distance, used by the pure-python oracle and the theory tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Large primes for the universal rehash that folds K sub-hash integers into a
# single table index.  Classic Carter–Wegman style mixing.
_MIX_PRIME = np.int64(2038074743)
_MIX_A = np.int64(1103515245)
_MIX_B = np.int64(12345)


def row_salts(n_rows: int, start=0) -> jnp.ndarray:
    """Golden-ratio fold salts for sketch rows ``[start, start + n_rows)``.

    The fold salt is a function of the *global* row index; sharded decode
    paths that evaluate a contiguous row slice (kernels/fused_decode's
    shard_map path) must pass the offset salts explicitly or their buckets
    diverge from the single-device hash.  ``start`` may be traced (it comes
    from ``jax.lax.axis_index`` inside shard_map).
    """
    rows = jnp.arange(n_rows, dtype=jnp.int32) + start
    return rows.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)


def _fold_subhashes(codes: jnp.ndarray, n_buckets: int,
                    salt: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fold ``(..., L, K)`` integer sub-hash codes into ``(..., L)`` indices.

    Carter–Wegman-style iterated affine mix in uint32, **salted by the row
    index**: each of the L sketch rows must realize an *independent* bucket
    map — without the salt, rows whose p-stable codes coincide (tiny code
    support at k=1!) collapse onto identical buckets and the sketch loses
    its i.i.d.-rows guarantee (caught by the bucket-uniformity test).
    ``salt`` overrides the default ``row_salts(L)`` (row-sharded callers).
    """
    codes = codes.astype(jnp.uint32)
    k = codes.shape[-1]
    n_rows = codes.shape[-2]
    if salt is None:
        salt = row_salts(n_rows)
    acc = jnp.broadcast_to(salt, codes.shape[:-1]).astype(jnp.uint32)
    for i in range(k):
        acc = acc * jnp.uint32(_MIX_A & 0xFFFFFFFF) + codes[..., i] + jnp.uint32(i * 97 + 13)
        acc = acc ^ (acc >> 16)
        acc = acc * jnp.uint32(0x45D9F3B)
        acc = acc ^ (acc >> 16)
    return (acc % jnp.uint32(n_buckets)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    """Static configuration of a concatenated LSH bank.

    Attributes:
      n_rows:    L — number of independent sketch rows.
      n_buckets: R — number of buckets (columns) per row.
      k:         number of concatenated sub-hashes per row.
      bandwidth: r — quantization width of the p-stable scheme (L2 only).
      dim:       input dimensionality d (or d' after the asymmetric transform).
    """

    n_rows: int
    n_buckets: int
    k: int
    dim: int
    bandwidth: float = 1.0


class L2LSH:
    """p-stable Euclidean LSH (Datar et al.), the paper's universal kernel."""

    def __init__(self, config: LSHConfig):
        self.config = config

    def params(self, key: jax.Array) -> dict:
        c = self.config
        kw, kb = jax.random.split(key)
        w = jax.random.normal(kw, (c.n_rows, c.k, c.dim), dtype=jnp.float32)
        b = jax.random.uniform(kb, (c.n_rows, c.k), minval=0.0, maxval=c.bandwidth)
        return {"w": w, "b": b}

    def subhash(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        """Return raw integer sub-hash codes with shape ``(..., L, K)``."""
        c = self.config
        # (..., d) @ (L, K, d) -> (..., L, K)
        proj = jnp.einsum("...d,lkd->...lk", x, params["w"])
        return jnp.floor((proj + params["b"]) / c.bandwidth).astype(jnp.int32)

    def hash(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        return _fold_subhashes(self.subhash(params, x), self.config.n_buckets)

    def collision_probability(self, dist: jnp.ndarray) -> jnp.ndarray:
        """L2-LSH kernel: P[h(x)=h(y)] as a function of c = ||x-y||_2.

        Closed form from Datar et al.:
          p(c) = 1 - 2·Phi(-r/c) - (2c / (sqrt(2π) r)) (1 - exp(-r²/(2c²)))
        Returns the K-fold power (independent concatenation).
        """
        r = self.config.bandwidth
        c = jnp.maximum(dist, 1e-9)
        t = r / c
        phi = 0.5 * (1.0 + jax.scipy.special.erf(-t / jnp.sqrt(2.0)))
        p1 = 1.0 - 2.0 * phi - (2.0 / (jnp.sqrt(2.0 * jnp.pi) * t)) * (
            1.0 - jnp.exp(-(t * t) / 2.0)
        )
        p1 = jnp.where(dist <= 1e-9, 1.0, p1)
        return jnp.clip(p1, 0.0, 1.0) ** self.config.k


class SRPLSH:
    """Sign random projection LSH; collision prob 1 − θ/π (angular kernel)."""

    def __init__(self, config: LSHConfig):
        self.config = config

    def params(self, key: jax.Array) -> dict:
        c = self.config
        w = jax.random.normal(key, (c.n_rows, c.k, c.dim), dtype=jnp.float32)
        return {"w": w}

    def subhash(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        proj = jnp.einsum("...d,lkd->...lk", x, params["w"])
        return (proj >= 0).astype(jnp.int32)

    def hash(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        # K sign bits pack exactly into an integer code; when 2^K <= R the
        # packed code *is* the bucket index (no mixing needed), otherwise mix.
        c = self.config
        bits = self.subhash(params, x)
        if 2**c.k <= c.n_buckets:
            weights = (2 ** np.arange(c.k)).astype(np.int32)
            return jnp.tensordot(bits, jnp.asarray(weights), axes=([-1], [0]))
        return _fold_subhashes(bits, c.n_buckets)

    def collision_probability(self, cos_sim: jnp.ndarray) -> jnp.ndarray:
        theta = jnp.arccos(jnp.clip(cos_sim, -1.0, 1.0))
        return (1.0 - theta / jnp.pi) ** self.config.k


class AchlioptasL2LSH(L2LSH):
    """L2 LSH with the sparse ±1 projection of Achlioptas [37].

    Entries are drawn from ``sqrt(3)·{+1, 0, −1}`` w.p. ``{1/6, 2/3, 1/6}``;
    this matches the paper's inference-time hash (add/sub only on edge
    hardware).  The projection is still a valid JL/p-stable surrogate; the
    collision probability is approximately the Gaussian one for d ≳ 30.
    """

    def params(self, key: jax.Array) -> dict:
        c = self.config
        kw, kb = jax.random.split(key)
        u = jax.random.uniform(kw, (c.n_rows, c.k, c.dim))
        w = jnp.sqrt(3.0) * (
            (u < 1.0 / 6.0).astype(jnp.float32) - (u > 5.0 / 6.0).astype(jnp.float32)
        )
        b = jax.random.uniform(kb, (c.n_rows, c.k), minval=0.0, maxval=c.bandwidth)
        return {"w": w, "b": b}


def make_lsh(kind: str, config: LSHConfig):
    if kind == "l2":
        return L2LSH(config)
    if kind == "srp":
        return SRPLSH(config)
    if kind == "achlioptas":
        return AchlioptasL2LSH(config)
    raise ValueError(f"unknown LSH kind: {kind}")
