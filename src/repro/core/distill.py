"""Teacher → kernel-student distillation (the paper's §3.4 'whole recipe').

Pipeline:
  1. Train (or receive) a teacher network f_N.
  2. Fit the kernel model f_K(q) = Σ α_j K(A^T q, x_j) to f_N's *outputs*
     with MSE loss and gradient descent (Adam), M ≪ N anchors.
  3. Freeze f_K into a RepresenterSketch for deployment.

The teacher here is a plain-JAX MLP (repro.core.teacher) — the paper's
experiments all use MLPs on tabular data.  Everything is jit-compiled and
runs in minutes on CPU for the paper-scale problems.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernel_model import KernelModel, KernelModelConfig


@dataclasses.dataclass(frozen=True)
class DistillConfig:
    n_steps: int = 2000
    batch_size: int = 256
    lr: float = 3e-3
    weight_decay: float = 0.0
    # L1 penalty on the alphas: the sketch's bucket-collision noise floor
    # scales with Σ|α|/√R (Theorem 1's variance bound), so sparse small-mass
    # alphas directly buy estimation accuracy per unit of sketch memory.
    alpha_l1: float = 0.0


def _adam_init(params):
    return {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def _adam_update(params, grads, state, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), mu)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), nu)
    new_params = jax.tree.map(
        lambda p, m, v: p - lr * (m / (jnp.sqrt(v) + eps) + wd * p),
        params,
        mhat,
        vhat,
    )
    return new_params, {"mu": mu, "nu": nu, "t": t}


def distill(
    key: jax.Array,
    teacher_fn: Callable[[jnp.ndarray], jnp.ndarray],
    train_x: jnp.ndarray,
    model: KernelModel,
    config: DistillConfig = DistillConfig(),
) -> Tuple[dict, Dict[str, float]]:
    """Fit ``model`` to ``teacher_fn`` on the (unlabeled) inputs ``train_x``.

    Returns the learned kernel-model params and a small metrics dict.
    The teacher's outputs are the regression targets (MSE risk), exactly as
    in Figure 1 of the paper.
    """
    k_init, k_anchor, k_loop = jax.random.split(key, 3)
    params = model.init(k_init)
    # Anchor the points on (projected) data samples — random-normal init
    # leaves whole data regions uncovered by the narrow k-fold LSH kernel
    # and the fit can collapse (observed on the phishing task).
    m = model.config.n_points
    idx = jax.random.randint(k_anchor, (m,), 0, train_x.shape[0])
    params["points"] = model.transform(params, train_x[idx])
    opt = _adam_init(params)
    targets = teacher_fn(train_x)  # soft targets — logits / regression output
    # Standardize targets for conditioning; fold the scale back into the
    # (linear) alphas afterwards.
    t_scale = jnp.maximum(jnp.std(targets), 1e-6)
    targets = targets / t_scale
    n = train_x.shape[0]

    def loss_fn(p, xb, yb):
        pred = model.apply(p, xb)
        mse = jnp.mean((pred - yb) ** 2)
        if config.alpha_l1:
            mse = mse + config.alpha_l1 * jnp.mean(jnp.abs(p["alphas"]))
        return mse

    @jax.jit
    def step(carry, key_step):
        p, o = carry
        idx = jax.random.randint(key_step, (config.batch_size,), 0, n)
        xb, yb = train_x[idx], targets[idx]
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, o = _adam_update(p, grads, o, config.lr, config.weight_decay)
        return (p, o), loss

    keys = jax.random.split(k_loop, config.n_steps)
    (params, opt), losses = jax.lax.scan(step, (params, opt), keys)
    final_loss = float(
        loss_fn(params, train_x[: min(n, 4096)], targets[: min(n, 4096)])
    )
    params = dict(params, alphas=params["alphas"] * t_scale)
    return params, {
        "final_mse": final_loss,
        "first_loss": float(losses[0]),
        "last_loss": float(losses[-1]),
    }
