"""Weighted RACE sketch (the paper's §3.2) and median-of-means queries.

A sketch is an ``(L, R)`` float array per output channel.  For multi-output
functions (C classes / regression targets) we store ``(C, L, R)`` — the paper
notes the linear-in-classes growth as its one limitation (§4.6).

Construction (Algorithm 1)::

    S[l, h_l(x_i)] += alpha_i          for every point, every row

Query (Algorithm 2)::

    z_l = S[l, h_l(q)]                 L row reads
    means = group-average(z, g)        g groups of L/g
    f_hat(q) = median(means)           median-of-means

Everything is pure JAX (jit/vmap friendly); the Pallas kernels in
``repro.kernels.race_query`` / ``race_update`` provide the TPU-tiled fast
paths and are validated against this module in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lsh import LSHConfig, make_lsh


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    n_rows: int          # L
    n_buckets: int       # R
    k: int               # concatenation depth per row
    dim: int             # hashed dimensionality (d or d' post-projection)
    n_outputs: int = 1   # C — number of output channels (classes/targets)
    bandwidth: float = 1.0
    lsh_kind: str = "l2"
    n_groups: int = 8    # g for median-of-means

    @property
    def lsh_config(self) -> LSHConfig:
        return LSHConfig(
            n_rows=self.n_rows,
            n_buckets=self.n_buckets,
            k=self.k,
            dim=self.dim,
            bandwidth=self.bandwidth,
        )

    @property
    def memory_floats(self) -> int:
        """Number of stored floats — the paper's memory metric (§4.3)."""
        return self.n_outputs * self.n_rows * self.n_buckets


class RepresenterSketch:
    """Weighted RACE sketch with MoM queries."""

    def __init__(self, config: SketchConfig):
        self.config = config
        self.lsh = make_lsh(config.lsh_kind, config.lsh_config)

    # -- parameters ---------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        """Hash params + an empty sketch array (+ total inserted mass).

        ``mass`` tracks Σ_i α_i per output channel: the universal rehash
        that folds K sub-hashes into R buckets collides *unrelated* points
        with probability 1/R, so E[S[h(q)]] = (1−1/R)·KDE + Σα/R.  Queries
        subtract the Σα/R floor and rescale — an unbiasedness correction
        the RACE construction doesn't need (its hashes are range-exact)
        but the paper's composed hash does (EXPERIMENTS.md §Paper).
        """
        return {
            "hash": self.lsh.params(key),
            "array": jnp.zeros(
                (self.config.n_outputs, self.config.n_rows, self.config.n_buckets),
                dtype=jnp.float32,
            ),
            "mass": jnp.zeros((self.config.n_outputs,), jnp.float32),
        }

    # -- construction (Algorithm 1) -----------------------------------------

    def build(self, state: dict, points: jnp.ndarray, alphas: jnp.ndarray) -> dict:
        """Insert ``points`` (M, d) with weights ``alphas`` (M, C) into the sketch.

        Implemented as a dense one-hot accumulation so it lowers to matmuls on
        the MXU rather than serial scatters (DESIGN.md §3).
        """
        cfg = self.config
        idx = self.lsh.hash(state["hash"], points)  # (M, L)
        onehot = jax.nn.one_hot(idx, cfg.n_buckets, dtype=jnp.float32)  # (M, L, R)
        if alphas.ndim == 1:
            alphas = alphas[:, None]
        # (C, L, R) = sum_m alphas[m, c] * onehot[m, l, r]
        arr = jnp.einsum("mc,mlr->clr", alphas.astype(jnp.float32), onehot)
        return {
            "hash": state["hash"],
            "array": state["array"] + arr,
            "mass": state["mass"] + jnp.sum(alphas.astype(jnp.float32), axis=0),
        }

    def build_streaming(
        self, state: dict, points: jnp.ndarray, alphas: jnp.ndarray, chunk: int = 4096
    ) -> dict:
        """Chunked build for datasets too large for a single one-hot tensor."""
        m = points.shape[0]
        out = state
        for start in range(0, m, chunk):
            out = self.build(out, points[start : start + chunk], alphas[start : start + chunk])
        return out

    # -- query (Algorithm 2) --------------------------------------------------

    def row_reads(self, state: dict, queries: jnp.ndarray) -> jnp.ndarray:
        """Return the raw ``(B, C, L)`` row reads ``S[c, l, h_l(q)]``."""
        idx = self.lsh.hash(state["hash"], queries)  # (B, L)
        arr = state["array"]  # (C, L, R)
        return jnp.take_along_axis(
            arr[None],  # (1, C, L, R)
            idx[:, None, :, None],  # (B, 1, L, 1)
            axis=-1,
        )[..., 0]

    def query(self, state: dict, queries: jnp.ndarray, mom: bool = True) -> jnp.ndarray:
        """Estimate the weighted KDE for a batch of queries → (B, C).

        ``mom=True`` uses median-of-means with g groups (the analyzed
        estimator); ``mom=False`` uses the plain average (the paper notes both
        perform comparably).
        """
        cfg = self.config
        reads = self.row_reads(state, queries)  # (B, C, L)
        # Debias the 1/R rehash-collision floor (see init docstring).
        r = cfg.n_buckets
        reads = (reads - state["mass"][None, :, None] / r) / (1.0 - 1.0 / r)
        if not mom:
            return jnp.mean(reads, axis=-1)
        g = cfg.n_groups
        l = cfg.n_rows
        m = l // g
        grouped = reads[..., : g * m].reshape(*reads.shape[:-1], g, m)
        means = jnp.mean(grouped, axis=-1)  # (B, C, g)
        return jnp.median(means, axis=-1)

    # -- direct (un-sketched) weighted KDE, for validation --------------------

    def exact_weighted_kde(
        self, points: jnp.ndarray, alphas: jnp.ndarray, queries: jnp.ndarray
    ) -> jnp.ndarray:
        """Exact ``Σ_i α_i K(q, x_i)`` using the closed-form collision kernel."""
        if alphas.ndim == 1:
            alphas = alphas[:, None]
        dist = jnp.linalg.norm(queries[:, None, :] - points[None, :, :], axis=-1)
        kern = self.lsh.collision_probability(dist)  # (B, M)
        return kern @ alphas.astype(jnp.float32)  # (B, C)


def mom_estimate(reads: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """Standalone median-of-means over the last axis (used by kernels' ref)."""
    l = reads.shape[-1]
    m = l // n_groups
    grouped = reads[..., : n_groups * m].reshape(*reads.shape[:-1], n_groups, m)
    return jnp.median(jnp.mean(grouped, axis=-1), axis=-1)
