"""The weighted kernel representation  f_K(q) = Σ_j α_j · K(A^T q, x_j).

This is the paper's §3.3/§3.4 object: a *learnable* weighted LSH-kernel sum.
Trainable parameters (per §3.4 and the asymmetric-LSH trick of §4.3):

* ``points``  x_j ∈ R^{d'}  — M anchor points living in the *projected* space,
* ``alphas``  α_j ∈ R^C     — per-point weights (one per output channel),
* ``proj``    A ∈ R^{d×d'}  — the asymmetric linear transform applied to
  queries only (Corollary 1 guarantees this preserves universality since a
  linear map restricted to the data manifold is injective a.s.).

During *training* we evaluate the smooth closed-form L2-LSH collision kernel
so gradients flow; at *deployment* the function is frozen into a
RepresenterSketch (hash + gather + MoM only).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.lsh import LSHConfig, L2LSH
from repro.core.sketch import RepresenterSketch, SketchConfig


@dataclasses.dataclass(frozen=True)
class KernelModelConfig:
    in_dim: int          # d  — raw feature dimensionality
    proj_dim: int        # d' — asymmetric projected dimensionality
    n_points: int        # M  — number of anchor points (M << N)
    n_outputs: int       # C
    bandwidth: float = 1.0
    k: int = 1           # concatenation depth used at sketch time


class KernelModel:
    """Differentiable weighted LSH-kernel sum + its frozen sketch form."""

    def __init__(self, config: KernelModelConfig):
        self.config = config
        # A single-row LSH bank is enough to define the kernel shape for
        # training; the sketch re-draws L independent rows at freeze time.
        self._kernel_lsh = L2LSH(
            LSHConfig(n_rows=1, n_buckets=2, k=config.k, dim=config.proj_dim,
                      bandwidth=config.bandwidth)
        )

    def init(self, key: jax.Array) -> dict:
        c = self.config
        kp, ka, kA = jax.random.split(key, 3)
        return {
            "points": 0.1 * jax.random.normal(kp, (c.n_points, c.proj_dim)),
            "alphas": 0.01 * jax.random.normal(ka, (c.n_points, c.n_outputs)),
            "proj": jax.random.normal(kA, (c.in_dim, c.proj_dim))
            / jnp.sqrt(c.in_dim),
        }

    def transform(self, params: dict, q: jnp.ndarray) -> jnp.ndarray:
        """Asymmetric query transform  T(q) = A^T q."""
        return q @ params["proj"]

    def apply(self, params: dict, q: jnp.ndarray) -> jnp.ndarray:
        """Smooth forward pass: (B, d) → (B, C).

        Uses the closed-form L2-LSH collision probability as the kernel, so
        this *is* the function the sketch will estimate (Theorem 1 says the
        sketch is unbiased for exactly this quantity).
        """
        tq = self.transform(params, q)  # (B, d')
        dist = jnp.sqrt(
            jnp.maximum(
                jnp.sum(tq * tq, -1)[:, None]
                - 2.0 * tq @ params["points"].T
                + jnp.sum(params["points"] ** 2, -1)[None, :],
                1e-12,
            )
        )  # (B, M)
        kern = self._kernel_lsh.collision_probability(dist)
        return kern @ params["alphas"]

    # -- freeze into a Representer Sketch -------------------------------------

    def sketch_config(self, n_rows: int, n_buckets: int, n_groups: int = 8) -> SketchConfig:
        c = self.config
        return SketchConfig(
            n_rows=n_rows,
            n_buckets=n_buckets,
            k=c.k,
            dim=c.proj_dim,
            n_outputs=c.n_outputs,
            bandwidth=c.bandwidth,
            lsh_kind="l2",
            n_groups=n_groups,
        )

    def freeze(
        self, key: jax.Array, params: dict, n_rows: int, n_buckets: int,
        n_groups: int = 8,
    ) -> Tuple[RepresenterSketch, dict]:
        """Build the deployment sketch from learned (points, alphas)."""
        sk = RepresenterSketch(self.sketch_config(n_rows, n_buckets, n_groups))
        state = sk.init(key)
        state = sk.build_streaming(state, params["points"], params["alphas"])
        return sk, state

    # -- cost accounting (paper §4.3 formulas) ---------------------------------

    def sketch_memory_params(self, n_rows: int, n_buckets: int) -> int:
        """Stored parameter count: array (C·L·R) + projection (d·d')."""
        c = self.config
        return c.n_outputs * n_rows * n_buckets + c.in_dim * c.proj_dim

    def sketch_flops(self, n_rows: int, n_buckets: int) -> int:
        """Paper's FLOP model: 2·d·p + p·K·L/3 + L (per query, per output).

        (The paper writes R where the hash-count is meant; with concatenation
        depth K and L rows there are K·L hash functions, each a sparse
        Achlioptas projection touching p/3 nonzeros.)
        """
        c = self.config
        return int(
            2 * c.in_dim * c.proj_dim
            + c.proj_dim * c.k * n_rows / 3
            + n_rows * c.n_outputs
        )


def mlp_memory_params(layer_sizes: Tuple[int, ...]) -> int:
    """Dense-MLP parameter count (weights + biases) for the NN baseline."""
    total = 0
    for a, b in zip(layer_sizes[:-1], layer_sizes[1:]):
        total += a * b + b
    return total


def mlp_flops(layer_sizes: Tuple[int, ...]) -> int:
    """Per-query multiply-accumulate FLOPs of the dense MLP baseline."""
    return int(sum(2 * a * b for a, b in zip(layer_sizes[:-1], layer_sizes[1:])))
