"""Representer Sketch core: LSH families, weighted RACE sketch, distillation."""

from repro.core.lsh import LSHConfig, L2LSH, SRPLSH, AchlioptasL2LSH, make_lsh
from repro.core.sketch import SketchConfig, RepresenterSketch, mom_estimate
from repro.core.kernel_model import (
    KernelModel,
    KernelModelConfig,
    mlp_flops,
    mlp_memory_params,
)
from repro.core.distill import DistillConfig, distill
from repro.core import theory

__all__ = [
    "LSHConfig", "L2LSH", "SRPLSH", "AchlioptasL2LSH", "make_lsh",
    "SketchConfig", "RepresenterSketch", "mom_estimate",
    "KernelModel", "KernelModelConfig", "mlp_flops", "mlp_memory_params",
    "DistillConfig", "distill", "theory",
]
