"""Representer-Sketch LM head: distill a dense logit head into per-class
RACE arrays (DESIGN.md §4 — the paper's technique as a serving feature).

The dense head computes ``logits = h · Wᵀ`` (2·d·V FLOPs/token).  We treat
each vocab class v as one output channel of a weighted kernel function

    f_K(h)[v] = Σ_j α_{j,v} · K(Aᵀh, x_j)

with *shared* anchors x_j and a shared asymmetric projection A (§4.3 of the
paper), distilled from the dense head's logits by MSE.  Freezing gives one
(L, R, V) sketch whose decode cost is L·V adds + a d×d' projection —
replacing 2·d·V multiplies.  The paper's noted limitation (memory linear in
V) is explicit here: memory = L·R·V vs d·V dense, a win iff L·R < d — and
the *storage* claim (up to 114×) additionally needs the counts narrower
than f32: ``quant="int8"|"int4"`` stores per-row symmetric-quantized counts
plus (L, R) f32 scales, dequantized in-register by the decode kernels
(DESIGN.md §12).

Decode-path kernels: repro.kernels.fused_decode (transform → hash → gather in
one pallas_call — the serving default), or the two-kernel composition of
repro.kernels.lsh_hash (projection+hash) and repro.kernels.sketch_head
(shared-index gather as MXU one-hot matvec), kept as the unfused baseline.
"""

from __future__ import annotations

import dataclasses
import types
import typing
import warnings
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import DistillConfig, distill
from repro.core.kernel_model import KernelModel, KernelModelConfig
from repro.core.lsh import L2LSH, LSHConfig
from repro.kernels.common import pack_int4_rows, unpack_int4_rows
from repro.kernels.fused_decode.ops import fused_decode_logits
from repro.kernels.lsh_hash.ops import lsh_hash
from repro.kernels.race_update.ops import race_update
from repro.kernels.sketch_head.ops import sketch_head_logits
from repro.models.config import SketchHeadConfig
from repro.optim.compress import quantize_symmetric

#: Count-array storage modes.  ``quant`` is *static* everywhere (it selects
#: kernel code paths); the scales travel in the head dict as a traced leaf.
QUANT_MODES = (None, "int8", "int4")

#: Current .npz archive format.  v1 = pre-version f32-only archives (still
#: loadable); v2 adds ``meta_format_version`` / ``meta_quant`` / ``scale``.
HEAD_FORMAT_VERSION = 2


def distill_head(
    key: jax.Array,
    head_table: jnp.ndarray,          # (V, d) dense head weights
    hidden_samples: jnp.ndarray,      # (N, d) representative final hiddens
    cfg: SketchHeadConfig,
    *,
    n_points: int = 512,
    distill_cfg: DistillConfig = DistillConfig(n_steps=1500, lr=5e-3),
) -> Tuple[dict, Dict[str, float]]:
    """Learn (anchors, alphas, proj) matching the dense head's logits."""
    v, d = head_table.shape
    model = KernelModel(KernelModelConfig(
        in_dim=d, proj_dim=cfg.proj_dim, n_points=n_points, n_outputs=v,
        bandwidth=cfg.bandwidth, k=cfg.k))
    teacher = lambda h: (h.astype(jnp.float32)
                         @ head_table.astype(jnp.float32).T)
    params, metrics = distill(key, teacher, hidden_samples, model, distill_cfg)
    return params, metrics


def _check_quant(quant: Optional[str]) -> None:
    if quant not in QUANT_MODES:
        raise ValueError(f"unknown quant mode {quant!r}; "
                         f"expected one of {QUANT_MODES}")


def quantize_counts(array: jnp.ndarray, quant: str,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric quantization of an (L, R, V) count array.

    Returns ``(store, scale)``: the int8 storage carrier — (L, R, V) int8
    for ``"int8"``, (⌈L/2⌉, R, V) packed bytes for ``"int4"`` — and the
    (L, R) f32 per-row scales.  One scale per gathered V-row keeps the
    dequant a single multiply inside the decode kernels.
    """
    _check_quant(quant)
    bits = {"int8": 8, "int4": 4}[quant]
    q, scale = quantize_symmetric(array, bits=bits, axis=-1)
    if quant == "int4":
        q = pack_int4_rows(q)
    return q, scale


def quantize_head(head: dict, quant: Optional[str]) -> dict:
    """Quantize a frozen f32 head's count array in place of ``"array"``.

    Adds the ``"scale"`` leaf; hash/transform params stay f32 (they are
    negligible next to the counts — see :func:`head_costs`).  ``None`` is a
    no-op copy, so callers can thread a config switch straight through.
    """
    _check_quant(quant)
    if "scale" in head:
        raise ValueError("head is already quantized (has a 'scale' leaf)")
    if quant is None:
        return dict(head)
    store, scale = quantize_counts(head["array"], quant)
    out = dict(head)
    out["array"] = store
    out["scale"] = scale
    return out


def dequantize_head(head: dict, quant: Optional[str],
                    n_rows: Optional[int] = None) -> dict:
    """Materialize the f32 head back from quantized storage (debug/eval).

    ``n_rows`` (true L) is needed for int4 only when it cannot be read off
    the hash bank ``head["w"]``.
    """
    _check_quant(quant)
    if quant is None:
        return dict(head)
    store = head["array"]
    if quant == "int4":
        l = n_rows if n_rows is not None else head["w"].shape[0]
        store = unpack_int4_rows(store, l)
    out = {k: v for k, v in head.items() if k != "scale"}
    out["array"] = store.astype(jnp.float32) * head["scale"][:, :, None]
    return out


def freeze_head(key: jax.Array, kernel_params: dict,
                cfg: SketchHeadConfig, *,
                quant: Optional[str] = None) -> dict:
    """Build the deployable sketch-head params from distilled kernel params.

    ``quant`` quantizes the count array on freeze (int8/int4 per-row
    symmetric; adds a ``"scale"`` leaf) — the deployable artifact never
    materializes f32 counts again.
    """
    points = kernel_params["points"]      # (M, d')
    alphas = kernel_params["alphas"]      # (M, V)
    lsh = L2LSH(LSHConfig(n_rows=cfg.n_rows, n_buckets=cfg.n_buckets,
                          k=cfg.k, dim=cfg.proj_dim, bandwidth=cfg.bandwidth))
    hash_params = lsh.params(key)
    idx = lsh.hash(hash_params, points)   # (M, L)
    onehot = jax.nn.one_hot(idx, cfg.n_buckets, dtype=jnp.float32)  # (M,L,R)
    # (L, R, V) — class-shared layout for the decode kernel.
    array = jnp.einsum("mlr,mv->lrv", onehot, alphas.astype(jnp.float32))
    head = {
        "proj": kernel_params["proj"],            # (d, d')
        "w": hash_params["w"],                    # (L, K, d')
        "b": hash_params["b"],                    # (L, K)
        "array": array,                           # (L, R, V)
    }
    return quantize_head(head, quant)


def stack_heads(heads) -> dict:
    """Stack per-tenant frozen head dicts into one tenant-indexed bank.

    Every leaf gains a leading tenant axis T — the layout the multi-tenant
    decode paths gather from by slot tenant-id (DESIGN.md §14).  All heads
    must share shapes, dtypes, and quantization (the bank is one jit
    operand; mixed storage would need per-tenant executables).
    """
    heads = list(heads)
    if not heads:
        raise ValueError("stack_heads needs at least one head")
    keys = set(heads[0])
    for h in heads[1:]:
        if set(h) != keys:
            raise ValueError(
                f"cannot stack heads with different leaves: {sorted(keys)} "
                f"vs {sorted(h)} — mixed quantization across tenants is not "
                f"supported")
    return {k: jnp.stack([jnp.asarray(h[k]) for h in heads]) for k in keys}


def refresh_head(head: dict, cfg: SketchHeadConfig, hidden: jnp.ndarray,
                 *, alphas: Optional[jnp.ndarray] = None,
                 targets: Optional[jnp.ndarray] = None, lr: float = 1.0,
                 backend: Optional[str] = None) -> dict:
    """Fold live-traffic (hidden, logit) pairs into the count arrays online.

    The streaming-update path the RACE sketch was designed for
    (``kernels/race_update``, DESIGN.md §14): hash the (M, d_model) hiddens
    through the head's own transform + bank, then accumulate the per-point
    weights into the (L, R, V) counts.  Exactly one of

    * ``alphas`` — (M, V) direct fold: the new points join the anchor set
      with these representer weights, mathematically identical to
      :func:`freeze_head` over the augmented set (same einsum, so a
      refresh-then-publish matches offline re-distillation on the same
      stream up to f32 summation order);
    * ``targets`` — (M, V) residual fold for live traffic: the weights are
      ``lr · (targets − f(hidden))``, a functional-gradient step toward the
      observed teacher logits.

    ``head`` must be the f32 working copy (refresh accumulates in f32;
    dequantize a quantized head first and re-quantize on publish — the
    engine's double-buffered ``refresh``/``publish`` does both).
    """
    if "scale" in head:
        raise ValueError(
            "refresh_head accumulates in f32; dequantize the head first "
            "(dequantize_head) and re-quantize on publish — see "
            "ServeEngine.refresh")
    if (alphas is None) == (targets is None):
        raise ValueError("pass exactly one of alphas= (direct fold) / "
                         "targets= (residual fold)")
    q = hidden.astype(jnp.float32) @ head["proj"]
    idx = lsh_hash(q, head["w"], head["b"], bandwidth=cfg.bandwidth,
                   n_buckets=cfg.n_buckets, backend=backend)       # (M, L)
    if targets is not None:
        pred = apply_head(head, hidden, cfg, backend="ref")
        alphas = lr * (targets.astype(jnp.float32) - pred)
    # race_update accumulates a (C, L, R) sketch; the head stores (L, R, V).
    # One class per vocab entry: move V to the class axis and back.
    sk = jnp.moveaxis(head["array"], -1, 0)                        # (V, L, R)
    sk = race_update(sk, idx, alphas.astype(jnp.float32), backend=backend)
    out = dict(head)
    out["array"] = jnp.moveaxis(sk, 0, -1)
    return out


#: Decode backends of the sketched head (see repro.api.heads.SketchHead).
HEAD_BACKENDS = ("fused", "two_kernel", "ref")


def apply_head(head: dict, hidden: jnp.ndarray, cfg: SketchHeadConfig,
               *, backend: Optional[str] = None,
               kernel_backend: Optional[str] = None,
               quant: Optional[str] = None,
               mesh=None, tenant_ids: Optional[jnp.ndarray] = None,
               use_pallas=None, fused=None) -> jnp.ndarray:
    """Sketched logits for (B, d) final hiddens → (B, V).

    ``backend`` selects the decode path:

    * ``"fused"``      — the whole head in one pallas_call (the serving hot
      path — no HBM round trip on the (B, L) index tensor; default),
    * ``"two_kernel"`` — the lsh_hash → sketch_head composition kept as the
      unfused baseline,
    * ``"ref"``        — the pure-jnp oracle composition (CPU/CI parity).

    ``kernel_backend`` optionally forces the kernel registry's pallas/ref
    choice for this call (otherwise ``REPRO_KERNEL_BACKEND`` / the registry
    default applies); ``backend="ref"`` already pins it to ``"ref"``, so
    combining it with ``kernel_backend="pallas"`` is a contradiction and
    raises.  ``quant`` declares the head's count-array storage (static;
    must match the presence of the head's ``"scale"`` leaf).  ``mesh`` (a
    ``jax.sharding.Mesh`` with a ``model`` axis) runs the head on the
    row-sharded shard_map path: count arrays partitioned over ``model`` on
    the repetition axis, scales with their rows, one psum of the (B, V)
    partials per step (DESIGN.md §9) — any ``backend`` composes with it.
    ``tenant_ids`` ((B,) int32) selects the multi-tenant path (DESIGN.md
    §14): ``head`` is a tenant-stacked bank (:func:`stack_heads`, leading
    axis T on every leaf), each resident tenant's logits are computed over
    the full batch by the identical single-tenant path, and row ``b`` takes
    tenant ``tenant_ids[b]``'s row arithmetic-free — bitwise what a
    single-tenant run bound to that head emits.  ``use_pallas=`` /
    ``fused=`` are deprecated aliases.
    """
    if fused is not None or use_pallas is not None:
        warnings.warn(
            "apply_head(fused=..., use_pallas=...) is deprecated; pass "
            "backend='fused'|'two_kernel'|'ref' (and kernel_backend= for "
            "the pallas/ref choice) instead", DeprecationWarning,
            stacklevel=2)
        if backend is None:
            backend = "fused" if fused else "two_kernel"
        if kernel_backend is None and use_pallas is not None:
            kernel_backend = "pallas" if use_pallas else "ref"
    if backend is None:
        backend = "fused"
    if backend == "ref":
        if kernel_backend not in (None, "ref"):
            raise ValueError(
                "apply_head(backend='ref') is the pure-jnp oracle and always "
                f"runs kernel_backend='ref'; got kernel_backend="
                f"{kernel_backend!r} — drop it or use backend='fused'/"
                "'two_kernel' to pick the kernel implementation")
        backend, kernel_backend = "two_kernel", "ref"
    _check_quant(quant)
    if (quant is not None) != ("scale" in head):
        raise ValueError(
            f"quant={quant!r} inconsistent with head params: a quantized "
            "head carries a 'scale' leaf and needs the matching quant= "
            "(got scale " + ("present" if "scale" in head else "absent") + ")")
    scale = head.get("scale")
    if backend == "fused":
        return fused_decode_logits(
            hidden.astype(jnp.float32), head["proj"], head["w"], head["b"],
            head["array"], bandwidth=cfg.bandwidth, n_buckets=cfg.n_buckets,
            scale=scale, quant=quant, backend=kernel_backend, mesh=mesh,
            tenant_ids=tenant_ids)
    if backend != "two_kernel":
        raise ValueError(f"unknown sketch-head backend {backend!r}; "
                         f"expected one of {HEAD_BACKENDS}")
    if tenant_ids is not None:
        # Per-tenant transforms and hash banks: each tenant hashes the full
        # batch through its own (proj, w, b) — lsh_hash itself is unchanged
        # — and the (T, B, L) index stack feeds the tenant-aware gather.
        h32 = hidden.astype(jnp.float32)
        idx = jnp.stack([
            lsh_hash(h32 @ head["proj"][t], head["w"][t], head["b"][t],
                     bandwidth=cfg.bandwidth, n_buckets=cfg.n_buckets,
                     backend=kernel_backend)
            for t in range(head["w"].shape[0])])
        return sketch_head_logits(head["array"], idx, scale=scale,
                                  quant=quant, backend=kernel_backend,
                                  mesh=mesh, tenant_ids=tenant_ids)
    q = hidden.astype(jnp.float32) @ head["proj"]
    idx = lsh_hash(q, head["w"], head["b"], bandwidth=cfg.bandwidth,
                   n_buckets=cfg.n_buckets, backend=kernel_backend)
    return sketch_head_logits(head["array"], idx, scale=scale, quant=quant,
                              backend=kernel_backend, mesh=mesh)


def save_head(path, head: dict, cfg: SketchHeadConfig, *,
              kind: str = "sketch", backend: str = "fused",
              quant: Optional[str] = None) -> None:
    """Persist a frozen head (+ its static config) as a compressed .npz.

    ``kind`` / ``backend`` / ``quant`` are the head-registry identity
    (repro.api.heads); they round-trip through :func:`load_head_meta` so a
    loaded head serves on the same decode path it was saved with.  Archives
    carry ``meta_format_version`` (= :data:`HEAD_FORMAT_VERSION`); config
    fields whose value is ``None`` are skipped and restored from the
    dataclass defaults on load.
    """
    _check_quant(quant)
    if (quant is not None) != ("scale" in head):
        raise ValueError(f"quant={quant!r} inconsistent with head params "
                         "(see apply_head)")
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(
        path, **{k: np.asarray(v) for k, v in head.items()},
        meta_format_version=np.asarray(HEAD_FORMAT_VERSION),
        meta_kind=np.asarray(kind), meta_backend=np.asarray(backend),
        meta_quant=np.asarray("none" if quant is None else quant),
        **{f"cfg_{f.name}": getattr(cfg, f.name)
           for f in dataclasses.fields(cfg)
           if getattr(cfg, f.name) is not None})


def _coerce_config_value(value, typ):
    """Coerce one archived config value to its dataclass field type.

    Handles the types a config dataclass actually uses — int, float, bool,
    str, and Optional[...] of those — from the 0-d numpy arrays an .npz
    round-trip produces.  bool is checked before int (a bool *is* an int);
    unknown types fall back to the raw ``.item()`` value.
    """
    origin = typing.get_origin(typ)
    if origin is typing.Union or origin is getattr(types, "UnionType", None):
        args = [a for a in typing.get_args(typ) if a is not type(None)]
        if len(args) == 1:                  # Optional[T] → T (None values
            typ = args[0]                   # are never written, see save)
    v = value.item() if isinstance(value, np.ndarray) and value.ndim == 0 \
        else value
    if typ is bool:
        return bool(v)
    if typ is int:
        return int(v)
    if typ is float:
        return float(v)
    if typ is str:
        return str(v)
    return v


def coerce_config(cls, raw: Dict[str, object]):
    """Build a config dataclass from raw archive values, field-typed.

    ``raw`` maps field names to archived values; missing fields fall back
    to the dataclass defaults (forward compat for fields added after the
    archive was written).  Field types are resolved through
    ``typing.get_type_hints`` — the config module uses
    ``from __future__ import annotations``, so ``field.type`` is a string.
    """
    hints = typing.get_type_hints(cls)
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.name in raw:
            kwargs[f.name] = _coerce_config_value(raw[f.name], hints[f.name])
    return cls(**kwargs)


def _meta_from_archive(data) -> Dict[str, object]:
    quant = str(data["meta_quant"]) if "meta_quant" in data else "none"
    return {
        "format_version": (int(data["meta_format_version"])
                           if "meta_format_version" in data else 1),
        "kind": str(data["meta_kind"]) if "meta_kind" in data else "sketch",
        "backend": (str(data["meta_backend"])
                    if "meta_backend" in data else "fused"),
        "quant": None if quant == "none" else quant,
    }


def load_head_full(path) -> Tuple[dict, SketchHeadConfig, Dict[str, object]]:
    """One archive read → (frozen params, config, registry metadata).

    Accepts every archive version: v1 (pre-version, pre-quant, uncompressed)
    archives load unchanged as the historical default — the fused f32
    sketch head.  Metadata keys: ``format_version``, ``kind``, ``backend``,
    ``quant`` (``None`` for f32 heads).
    """
    with np.load(Path(path)) as data:
        keys = ["proj", "w", "b", "array"]
        if "scale" in data:
            keys.append("scale")
        head = {k: jnp.asarray(data[k]) for k in keys}
        cfg = coerce_config(SketchHeadConfig, {
            f.name: data[f"cfg_{f.name}"]
            for f in dataclasses.fields(SketchHeadConfig)
            if f"cfg_{f.name}" in data})
        meta = _meta_from_archive(data)
    if (meta["quant"] is not None) != ("scale" in head):
        raise ValueError(f"corrupt head archive {path}: meta_quant="
                         f"{meta['quant']!r} but scale leaf "
                         + ("present" if "scale" in head else "missing"))
    return head, cfg, meta


def load_head(path) -> Tuple[dict, SketchHeadConfig]:
    """Load a frozen head saved by :func:`save_head`."""
    head, cfg, _ = load_head_full(path)
    return head, cfg


def load_head_meta(path) -> Dict[str, object]:
    """Registry metadata of a saved head: ``{"format_version", "kind",
    "backend", "quant"}``."""
    with np.load(Path(path)) as data:
        return _meta_from_archive(data)


def head_costs(cfg: SketchHeadConfig, d_model: int, vocab: int,
               *, quant: Optional[str] = None) -> dict:
    """Analytic memory/FLOP comparison vs the dense head (paper §4.3 model).

    ``dense_params`` / ``sketch_params`` count *elements* (the historical
    fields — identical under quantization, which is why they understate the
    storage win); ``dense_bytes`` / ``sketch_bytes`` / ``bytes_ratio`` are
    dtype-aware: f32 counts are 4 B, int8 counts 1 B, packed int4 counts
    ½ B (+ the (L, R) f32 scales), hash/transform params always f32.
    """
    _check_quant(quant)
    dense_params = d_model * vocab
    n_counts = cfg.n_rows * cfg.n_buckets * vocab
    aux_params = (d_model * cfg.proj_dim            # asymmetric transform A
                  + cfg.n_rows * cfg.k * cfg.proj_dim)  # hash bank w
    sketch_params = n_counts + aux_params
    dense_flops = 2 * d_model * vocab
    sketch_flops = (2 * d_model * cfg.proj_dim            # projection
                    + 2 * cfg.proj_dim * cfg.k * cfg.n_rows  # hashing
                    + cfg.n_rows * vocab)                 # gather-mean adds

    if quant == "int8":
        count_bytes = n_counts                            # 1 B/count
    elif quant == "int4":
        count_bytes = -(-cfg.n_rows // 2) * cfg.n_buckets * vocab  # ½ B
    else:
        count_bytes = 4 * n_counts
    scale_bytes = 4 * cfg.n_rows * cfg.n_buckets if quant else 0
    dense_bytes = 4 * dense_params
    sketch_bytes = count_bytes + scale_bytes + 4 * aux_params
    return {
        "dense_params": dense_params,
        "sketch_params": sketch_params,
        "param_ratio": dense_params / sketch_params,
        "dense_bytes": dense_bytes,
        "sketch_bytes": sketch_bytes,
        "bytes_ratio": dense_bytes / sketch_bytes,
        "dense_flops": dense_flops,
        "sketch_flops": sketch_flops,
        "flop_ratio": dense_flops / sketch_flops,
    }
