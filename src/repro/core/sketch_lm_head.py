"""Representer-Sketch LM head: distill a dense logit head into per-class
RACE arrays (DESIGN.md §4 — the paper's technique as a serving feature).

The dense head computes ``logits = h · Wᵀ`` (2·d·V FLOPs/token).  We treat
each vocab class v as one output channel of a weighted kernel function

    f_K(h)[v] = Σ_j α_{j,v} · K(Aᵀh, x_j)

with *shared* anchors x_j and a shared asymmetric projection A (§4.3 of the
paper), distilled from the dense head's logits by MSE.  Freezing gives one
(L, R, V) sketch whose decode cost is L·V adds + a d×d' projection —
replacing 2·d·V multiplies.  The paper's noted limitation (memory linear in
V) is explicit here: memory = L·R·V vs d·V dense, a win iff L·R < d.

Decode-path kernels: repro.kernels.fused_decode (transform → hash → gather in
one pallas_call — the serving default), or the two-kernel composition of
repro.kernels.lsh_hash (projection+hash) and repro.kernels.sketch_head
(shared-index gather as MXU one-hot matvec), kept as the unfused baseline.
"""

from __future__ import annotations

import dataclasses
import warnings
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import DistillConfig, distill
from repro.core.kernel_model import KernelModel, KernelModelConfig
from repro.core.lsh import L2LSH, LSHConfig
from repro.kernels.fused_decode.ops import fused_decode_logits
from repro.kernels.lsh_hash.ops import lsh_hash
from repro.kernels.sketch_head.ops import sketch_head_logits
from repro.models.config import SketchHeadConfig


def distill_head(
    key: jax.Array,
    head_table: jnp.ndarray,          # (V, d) dense head weights
    hidden_samples: jnp.ndarray,      # (N, d) representative final hiddens
    cfg: SketchHeadConfig,
    *,
    n_points: int = 512,
    distill_cfg: DistillConfig = DistillConfig(n_steps=1500, lr=5e-3),
) -> Tuple[dict, Dict[str, float]]:
    """Learn (anchors, alphas, proj) matching the dense head's logits."""
    v, d = head_table.shape
    model = KernelModel(KernelModelConfig(
        in_dim=d, proj_dim=cfg.proj_dim, n_points=n_points, n_outputs=v,
        bandwidth=cfg.bandwidth, k=cfg.k))
    teacher = lambda h: (h.astype(jnp.float32)
                         @ head_table.astype(jnp.float32).T)
    params, metrics = distill(key, teacher, hidden_samples, model, distill_cfg)
    return params, metrics


def freeze_head(key: jax.Array, kernel_params: dict,
                cfg: SketchHeadConfig) -> dict:
    """Build the deployable sketch-head params from distilled kernel params."""
    points = kernel_params["points"]      # (M, d')
    alphas = kernel_params["alphas"]      # (M, V)
    lsh = L2LSH(LSHConfig(n_rows=cfg.n_rows, n_buckets=cfg.n_buckets,
                          k=cfg.k, dim=cfg.proj_dim, bandwidth=cfg.bandwidth))
    hash_params = lsh.params(key)
    idx = lsh.hash(hash_params, points)   # (M, L)
    onehot = jax.nn.one_hot(idx, cfg.n_buckets, dtype=jnp.float32)  # (M,L,R)
    # (L, R, V) — class-shared layout for the decode kernel.
    array = jnp.einsum("mlr,mv->lrv", onehot, alphas.astype(jnp.float32))
    return {
        "proj": kernel_params["proj"],            # (d, d')
        "w": hash_params["w"],                    # (L, K, d')
        "b": hash_params["b"],                    # (L, K)
        "array": array,                           # (L, R, V)
    }


#: Decode backends of the sketched head (see repro.api.heads.SketchHead).
HEAD_BACKENDS = ("fused", "two_kernel", "ref")


def apply_head(head: dict, hidden: jnp.ndarray, cfg: SketchHeadConfig,
               *, backend: Optional[str] = None,
               kernel_backend: Optional[str] = None,
               mesh=None, use_pallas=None, fused=None) -> jnp.ndarray:
    """Sketched logits for (B, d) final hiddens → (B, V).

    ``backend`` selects the decode path:

    * ``"fused"``      — the whole head in one pallas_call (the serving hot
      path — no HBM round trip on the (B, L) index tensor; default),
    * ``"two_kernel"`` — the lsh_hash → sketch_head composition kept as the
      unfused baseline,
    * ``"ref"``        — the pure-jnp oracle composition (CPU/CI parity).

    ``kernel_backend`` optionally forces the kernel registry's pallas/ref
    choice for this call (otherwise ``REPRO_KERNEL_BACKEND`` / the registry
    default applies).  ``mesh`` (a ``jax.sharding.Mesh`` with a ``model``
    axis) runs the head on the row-sharded shard_map path: count arrays
    partitioned over ``model`` on the repetition axis, one psum of the
    (B, V) partials per step (DESIGN.md §9) — any ``backend`` composes with
    it.  ``use_pallas=`` / ``fused=`` are deprecated aliases.
    """
    if fused is not None or use_pallas is not None:
        warnings.warn(
            "apply_head(fused=..., use_pallas=...) is deprecated; pass "
            "backend='fused'|'two_kernel'|'ref' (and kernel_backend= for "
            "the pallas/ref choice) instead", DeprecationWarning,
            stacklevel=2)
        if backend is None:
            backend = "fused" if fused else "two_kernel"
        if kernel_backend is None and use_pallas is not None:
            kernel_backend = "pallas" if use_pallas else "ref"
    if backend is None:
        backend = "fused"
    if backend == "ref":
        backend, kernel_backend = "two_kernel", "ref"
    if backend == "fused":
        return fused_decode_logits(
            hidden.astype(jnp.float32), head["proj"], head["w"], head["b"],
            head["array"], bandwidth=cfg.bandwidth, n_buckets=cfg.n_buckets,
            backend=kernel_backend, mesh=mesh)
    if backend != "two_kernel":
        raise ValueError(f"unknown sketch-head backend {backend!r}; "
                         f"expected one of {HEAD_BACKENDS}")
    q = hidden.astype(jnp.float32) @ head["proj"]
    idx = lsh_hash(q, head["w"], head["b"], bandwidth=cfg.bandwidth,
                   n_buckets=cfg.n_buckets, backend=kernel_backend)
    return sketch_head_logits(head["array"], idx, backend=kernel_backend,
                              mesh=mesh)


def save_head(path, head: dict, cfg: SketchHeadConfig, *,
              kind: str = "sketch", backend: str = "fused") -> None:
    """Persist a frozen head (+ its static config) as an .npz archive.

    ``kind`` / ``backend`` are the head-registry identity (repro.api.heads);
    they round-trip through :func:`load_head_meta` so a loaded head serves
    on the same decode path it was saved with.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in head.items()},
             meta_kind=np.asarray(kind), meta_backend=np.asarray(backend),
             **{f"cfg_{f.name}": getattr(cfg, f.name)
                for f in dataclasses.fields(cfg)})


def load_head_full(path) -> Tuple[dict, SketchHeadConfig, Dict[str, str]]:
    """One archive read → (frozen params, config, registry metadata).

    Archives written before the metadata existed load as the historical
    default, the fused sketch head.
    """
    with np.load(Path(path)) as data:
        head = {k: jnp.asarray(data[k]) for k in ("proj", "w", "b", "array")}
        fields = {f.name: f.type
                  for f in dataclasses.fields(SketchHeadConfig)}
        cfg = SketchHeadConfig(**{
            name: (float if "float" in str(typ) else int)(data[f"cfg_{name}"])
            for name, typ in fields.items()})
        meta = {"kind": (str(data["meta_kind"])
                         if "meta_kind" in data else "sketch"),
                "backend": (str(data["meta_backend"])
                            if "meta_backend" in data else "fused")}
    return head, cfg, meta


def load_head(path) -> Tuple[dict, SketchHeadConfig]:
    """Load a frozen head saved by :func:`save_head`."""
    head, cfg, _ = load_head_full(path)
    return head, cfg


def load_head_meta(path) -> Dict[str, str]:
    """Head-registry metadata of a saved head: ``{"kind", "backend"}``."""
    with np.load(Path(path)) as data:
        return {"kind": (str(data["meta_kind"])
                         if "meta_kind" in data else "sketch"),
                "backend": (str(data["meta_backend"])
                            if "meta_backend" in data else "fused")}


def head_costs(cfg: SketchHeadConfig, d_model: int, vocab: int) -> dict:
    """Analytic memory/FLOP comparison vs the dense head (paper §4.3 model)."""
    dense_params = d_model * vocab
    sketch_params = (cfg.n_rows * cfg.n_buckets * vocab
                     + d_model * cfg.proj_dim
                     + cfg.n_rows * cfg.k * cfg.proj_dim)
    dense_flops = 2 * d_model * vocab
    sketch_flops = (2 * d_model * cfg.proj_dim            # projection
                    + 2 * cfg.proj_dim * cfg.k * cfg.n_rows  # hashing
                    + cfg.n_rows * vocab)                 # gather-mean adds
    return {
        "dense_params": dense_params,
        "sketch_params": sketch_params,
        "param_ratio": dense_params / sketch_params,
        "dense_flops": dense_flops,
        "sketch_flops": sketch_flops,
        "flop_ratio": dense_flops / sketch_flops,
    }
