"""Plain-JAX MLP teacher networks matching the paper's Table 2 settings.

The paper trains MLPs (e.g. 512/256/128 hidden) on UCI tabular tasks and then
distills them.  We reproduce that substrate here: init, forward, and a small
Adam training loop for classification (logits + softmax CE) and regression
(scalar + MSE).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.distill import _adam_init, _adam_update


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    hidden: Tuple[int, ...]
    out_dim: int

    @property
    def layer_sizes(self) -> Tuple[int, ...]:
        return (self.in_dim, *self.hidden, self.out_dim)


def init_mlp(key: jax.Array, config: MLPConfig) -> list:
    sizes = config.layer_sizes
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b)) * jnp.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,))})
    return params


def mlp_forward(params: list, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def train_mlp(
    key: jax.Array,
    config: MLPConfig,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    task: str = "classification",
    n_steps: int = 2000,
    batch_size: int = 256,
    lr: float = 1e-3,
) -> Tuple[list, dict]:
    """Train the teacher. ``y`` is int labels (classification) or float targets."""
    k_init, k_loop = jax.random.split(key)
    params = init_mlp(k_init, config)
    opt = _adam_init(params)
    n = x.shape[0]

    def loss_fn(p, xb, yb):
        out = mlp_forward(p, xb)
        if task == "classification":
            logp = jax.nn.log_softmax(out)
            return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))
        return jnp.mean((out[:, 0] - yb) ** 2)

    @jax.jit
    def step(carry, key_step):
        p, o = carry
        idx = jax.random.randint(key_step, (batch_size,), 0, n)
        loss, grads = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, o = _adam_update(p, grads, o, lr, 0.0)
        return (p, o), loss

    keys = jax.random.split(k_loop, n_steps)
    (params, opt), losses = jax.lax.scan(step, (params, opt), keys)
    return params, {"first_loss": float(losses[0]), "last_loss": float(losses[-1])}


def accuracy(params: list, x: jnp.ndarray, y: jnp.ndarray) -> float:
    pred = jnp.argmax(mlp_forward(params, x), axis=-1)
    return float(jnp.mean(pred == y))


def mae(params: list, x: jnp.ndarray, y: jnp.ndarray) -> float:
    return float(jnp.mean(jnp.abs(mlp_forward(params, x)[:, 0] - y)))
