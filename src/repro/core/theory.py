"""Closed-form error-bound calculators for Theorems 1–2.

These are used by the property tests (empirical error must respect the bound)
and by the sizing helper that picks (L, R, K, g) for a target error budget —
the paper's 'relationship concerning the sketch memory and the estimation
error' (§3.4 Memory Requirement).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp


def mom_error_bound(sigma: float, n_rows: int, delta: float) -> float:
    """Lemma 1 / Theorem 2:  |Z − μ| ≤ 6·σ/√L·√log(1/δ)  w.p. 1−δ."""
    return 6.0 * sigma / math.sqrt(n_rows) * math.sqrt(math.log(1.0 / delta))


def variance_bound(alphas: jnp.ndarray, sqrt_kernels: jnp.ndarray) -> jnp.ndarray:
    """Theorem 1 variance bound:  var ≤ (Σ_i α_i √K(x_i,q))²  per query.

    Args:
      alphas: (M,) or (M, C) weights.
      sqrt_kernels: (B, M) values of √K(x_i, q).
    Returns (B,) or (B, C).
    """
    if alphas.ndim == 1:
        return (sqrt_kernels @ alphas) ** 2
    return (sqrt_kernels @ alphas) ** 2


def rows_for_error(sigma: float, eps: float, delta: float) -> int:
    """Invert Theorem 2: minimum L so the MoM error ≤ eps w.p. 1−δ."""
    return int(math.ceil((6.0 * sigma / eps) ** 2 * math.log(1.0 / delta)))


def mom_groups(delta: float) -> int:
    """Lemma 1's group count g = 8·log(1/δ) (rounded up, min 1)."""
    return max(1, int(math.ceil(8.0 * math.log(1.0 / delta))))


def size_sketch(
    sigma: float, eps: float, delta: float, n_buckets: int, n_outputs: int
) -> Tuple[int, int]:
    """Return (L, memory_floats) meeting the (eps, delta) target."""
    l = rows_for_error(sigma, eps, delta)
    return l, n_outputs * l * n_buckets
