"""Failure detection + recovery policy for 1000+-node fleets.

Pure-function policy core + a simulation-friendly registry, because this
container has no real cluster:

* ``HeartbeatRegistry`` — hosts report beats; ``missing(now)`` lists hosts
  past the timeout.
* ``decide_recovery`` — the supervisor policy: given fleet state, choose
  CONTINUE / SHRINK (elastic re-mesh without the dead hosts; data shards
  rebalanced) / RESTART (reload latest checkpoint; used when too many hosts
  died for a consistent shrink or a mesh axis can't be re-factored).
* ``StragglerTracker`` — per-host step-time EMA; hosts slower than
  ``threshold × median`` get flagged; policy first reassigns their data
  shard, then evicts on repeat offenses.

tests/test_runtime.py drives these through failure scripts (mid-step death,
cascades, flapping stragglers) and asserts invariants: work is never
assigned to dead hosts, shrink keeps the batch divisible, restart always
lands on a manifest-complete step.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple


class Action(enum.Enum):
    CONTINUE = "continue"
    SHRINK = "shrink"
    RESTART = "restart"


@dataclasses.dataclass
class RecoveryPlan:
    action: Action
    healthy_hosts: Tuple[int, ...]
    new_data_parallel: Optional[int] = None   # replicas after shrink
    reason: str = ""


class HeartbeatRegistry:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last_beat: Dict[int, float] = {h: 0.0 for h in hosts}

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_beat[host] = time.time() if now is None else now

    def missing(self, now: Optional[float] = None) -> List[int]:
        t = time.time() if now is None else now
        return sorted(h for h, b in self.last_beat.items()
                      if t - b > self.timeout)

    def healthy(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.missing(now))
        return sorted(h for h in self.last_beat if h not in dead)


def decide_recovery(
    n_hosts: int,
    dead: Sequence[int],
    *,
    hosts_per_replica: int,
    n_replicas: int,
    max_shrink_fraction: float = 0.25,
) -> RecoveryPlan:
    """Supervisor policy after failures.

    A data-parallel *replica* spans ``hosts_per_replica`` hosts (the model
    shards).  Losing any host kills its whole replica; the fleet can shrink
    by dropping dead replicas while > (1−max_shrink_fraction) capacity
    remains, otherwise it restarts from checkpoint waiting for replacements.
    """
    dead_set = set(dead)
    healthy = tuple(h for h in range(n_hosts) if h not in dead_set)
    if not dead_set:
        return RecoveryPlan(Action.CONTINUE, healthy, n_replicas, "no failures")

    dead_replicas = {h // hosts_per_replica for h in dead_set}
    alive_replicas = n_replicas - len(dead_replicas)
    if alive_replicas <= 0:
        return RecoveryPlan(Action.RESTART, healthy, None,
                            "all replicas affected")
    lost_frac = len(dead_replicas) / n_replicas
    if lost_frac <= max_shrink_fraction:
        return RecoveryPlan(
            Action.SHRINK, healthy, alive_replicas,
            f"dropping {len(dead_replicas)} replica(s), "
            f"{alive_replicas}/{n_replicas} remain")
    return RecoveryPlan(Action.RESTART, healthy, None,
                        f"{lost_frac:.0%} of replicas lost "
                        f"> {max_shrink_fraction:.0%} shrink budget")


class StragglerTracker:
    """Per-host step-time EMA with median-relative flagging."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.5,
                 evict_after: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.evict_after = evict_after
        self.ema: Dict[int, float] = {}
        self.offenses: Dict[int, int] = defaultdict(int)

    def record(self, host: int, step_time: float) -> None:
        prev = self.ema.get(host)
        self.ema[host] = (step_time if prev is None
                          else self.alpha * step_time + (1 - self.alpha) * prev)

    def stragglers(self) -> List[int]:
        if len(self.ema) < 2:
            return []
        times = sorted(self.ema.values())
        median = times[len(times) // 2]
        out = []
        for h, t in self.ema.items():
            if t > self.threshold * median:
                self.offenses[h] += 1
                out.append(h)
        return sorted(out)

    def to_evict(self) -> List[int]:
        return sorted(h for h, c in self.offenses.items()
                      if c >= self.evict_after)
