"""Elastic re-meshing: recompute mesh + batch partition after fleet changes.

When the supervisor SHRINKs, the job must keep running with fewer data
replicas: the mesh's data axis shrinks, the global batch is re-balanced
(either smaller global batch or more per-replica microbatching — policy
below keeps the global batch constant via gradient accumulation so the
training trajectory is unchanged), and data shards are reassigned away from
dead hosts deterministically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int                  # data-parallel replicas
    model: int                 # model shards per replica
    grad_accum: int            # microbatches per step
    shard_owner: Tuple[int, ...]  # data-shard index → host id
    global_batch: int = 0      # effective global batch under this plan


def initial_plan(n_hosts: int, hosts_per_replica: int,
                 global_batch: int) -> MeshPlan:
    data = n_hosts // hosts_per_replica
    assert global_batch % data == 0
    return MeshPlan(data, hosts_per_replica, 1,
                    tuple(r * hosts_per_replica for r in range(data)),
                    global_batch)


def shrink_plan(plan: MeshPlan, dead_hosts: Sequence[int],
                global_batch: int) -> MeshPlan:
    """Drop replicas containing dead hosts; rebalance the batch.

    Policy: keep the global batch exactly when divisibility allows
    (grad_accum over surviving replicas); otherwise keep the *per-replica*
    batch and shrink the global batch to ``new_data × per_replica`` — the
    supervisor rescales the LR by the batch ratio (noted in the audit log).
    """
    dead = set(dead_hosts)
    survivors = [owner for owner in plan.shard_owner
                 if not any(owner <= h < owner + plan.model for h in dead)]
    new_data = len(survivors)
    if new_data == 0:
        raise ValueError("no surviving replicas — RESTART required")
    per_replica = max(global_batch // max(plan.data, 1), 1)
    if global_batch % new_data == 0:
        micro = global_batch // new_data
        accum = max(1, -(-micro // per_replica))
        return MeshPlan(new_data, plan.model, accum, tuple(survivors),
                        global_batch)
    return MeshPlan(new_data, plan.model, 1, tuple(survivors),
                    new_data * per_replica)


def reassign_shards(plan: MeshPlan, n_shards: int) -> Dict[int, List[int]]:
    """Deterministic round-robin of data shards over surviving replicas."""
    owners: Dict[int, List[int]] = {o: [] for o in plan.shard_owner}
    for s in range(n_shards):
        owner = plan.shard_owner[s % plan.data]
        owners[owner].append(s)
    return owners
