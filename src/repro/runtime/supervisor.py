"""Training supervisor: the fault-tolerant outer loop.

Composes the substrate pieces — data loader, jitted train step, async
checkpointing, heartbeat/straggler policies, elastic re-mesh — into the
loop a real cluster controller would run per job:

    restore-from-latest → train → [failure?] → decide → shrink/restart → …

Failures are injected via the ``fault_hook`` callback (tests script them);
on real hardware the same decision points would be fed by the heartbeat
service instead.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import MeshPlan, initial_plan, shrink_plan
from repro.runtime.failure import (Action, HeartbeatRegistry, StragglerTracker,
                                   decide_recovery)


@dataclasses.dataclass
class SupervisorConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    n_hosts: int = 1
    hosts_per_replica: int = 1
    heartbeat_timeout_s: float = 60.0


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, *,
                 init_state: Callable[[], Dict],
                 step_fn: Callable[[Dict, Dict], Dict],
                 batch_fn: Callable[[int], Dict],
                 fault_hook: Optional[Callable[[int], list]] = None):
        """
        init_state: () → mutable train-state pytree dict
        step_fn:    (state, batch) → state (jitted inside)
        batch_fn:   step → host-local batch
        fault_hook: step → list of host ids that died this step (simulation)
        """
        self.cfg = cfg
        self.init_state = init_state
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook or (lambda step: [])
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.heartbeats = HeartbeatRegistry(range(cfg.n_hosts),
                                            cfg.heartbeat_timeout_s)
        self.stragglers = StragglerTracker()
        self.plan = initial_plan(cfg.n_hosts, cfg.hosts_per_replica,
                                 global_batch=max(cfg.n_hosts, 1))
        self.events: list = []   # audit log consumed by tests

    def run(self) -> Dict:
        state = self.init_state()
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, start = self.ckpt.restore(state, latest)
            self.events.append(("restored", latest))
            start = latest + 1

        step = start
        restarts = 0
        while step < self.cfg.total_steps:
            t0 = time.time()
            dead = self.fault_hook(step)
            if dead:
                plan = decide_recovery(
                    self.cfg.n_hosts, dead,
                    hosts_per_replica=self.cfg.hosts_per_replica,
                    n_replicas=self.plan.data)
                self.events.append(("failure", step, tuple(dead), plan.action))
                if plan.action is Action.SHRINK:
                    self.plan = shrink_plan(self.plan, dead,
                                            global_batch=max(self.cfg.n_hosts, 1))
                    self.events.append(("shrunk", step, self.plan.data))
                elif plan.action is Action.RESTART:
                    restarts += 1
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        state = self.init_state()
                        state, _ = self.ckpt.restore(state, latest)
                        step = latest + 1
                    else:
                        state = self.init_state()
                        step = 0
                    self.events.append(("restarted", step))
                    continue

            batch = self.batch_fn(step)
            state = self.step_fn(state, batch)
            self.stragglers.record(0, time.time() - t0)

            if step % self.cfg.ckpt_every == 0 and step > 0:
                self.ckpt.save(step, jax.tree.map(np.asarray, state))
                self.events.append(("saved", step))
            step += 1

        self.ckpt.wait()
        self.events.append(("done", step, restarts))
        return state
