"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required for the dry-run's
512-placeholder-device initialization order (launch/dryrun.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many local devices exist (tests/smoke)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
