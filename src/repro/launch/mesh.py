"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required for the dry-run's
512-placeholder-device initialization order (launch/dryrun.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many local devices exist (tests/smoke)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def place_serving_state(params, head, mesh):
    """Shard serving state onto ``mesh`` per ``sharding/rules.py``.

    The one placement path shared by the ``LM`` facade, the engine backend,
    and ``launch.serve.generate``: backbone params via ``params_shardings``,
    the head's frozen arrays (if any) via ``head_param_shardings``.  A
    no-op copy-wise when the arrays are already placed (``jax.device_put``
    short-circuits on matching shardings).

    Args:
      params: backbone parameter pytree.
      head: a ``repro.api`` LogitHead (its ``params`` may be ``None``).
      mesh: the target ``jax.sharding.Mesh``.

    Returns:
      ``(params, head)`` placed on the mesh.
    """
    from repro.sharding.rules import head_param_shardings, params_shardings

    params = jax.device_put(params, params_shardings(params, mesh))
    if head.params is not None:
        head = head.with_params(jax.device_put(
            head.params, head_param_shardings(head.params, mesh)))
    return params, head


def parse_mesh(spec):
    """A serving mesh from a ``"<data>x<model>"`` spec string.

    The CLI / API surface for sharded serving (``serve.py --mesh 4x2``,
    ``LM.from_config(mesh="4x2")``): builds a ``(data, model)`` mesh over
    the local devices.  Accepts an existing ``Mesh`` (returned unchanged)
    or ``None`` (returns ``None``) so callers can thread user input through
    without case analysis.

    Args:
      spec: ``None``, a ``jax.sharding.Mesh``, or a string like ``"4x2"``
        (data × model).

    Returns:
      A ``jax.sharding.Mesh`` with axes ``("data", "model")``, or ``None``.

    Raises:
      ValueError: on a malformed spec string or when the requested shape
        needs more devices than the process has (forced-CPU runs set
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    if spec is None or isinstance(spec, jax.sharding.Mesh):
        return spec
    try:
        data, model = (int(p) for p in str(spec).lower().split("x"))
    except ValueError:
        raise ValueError(
            f"mesh spec {spec!r} is not of the form '<data>x<model>' "
            f"(e.g. '4x2')") from None
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"mesh {spec!r} needs {data * model} devices but only {n} "
            f"are visible; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={data * model} for a forced-CPU mesh")
    return jax.make_mesh((data, model), ("data", "model"))
