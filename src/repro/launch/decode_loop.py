"""On-device decode megasteps: K tokens per dispatch (DESIGN.md §10).

The per-token serving loop pays three host costs the paper's cheap sketched
step cannot amortize: a Python-level jit dispatch per token, a device→host
sync to sample (the old ``np.asarray`` in the loop), and — without buffer
donation — a full decode-cache copy per step.  This module moves the loop
onto the device: one jitted **megastep** runs K decode steps as a
``jax.lax.scan`` whose carry is ``(cache, last_tok, pos, active, key)``,
with the :class:`repro.api.Sampler` (temperature / top-k / top-p, split-key
chain) and EOS → active-mask retirement fused *inside* the scan body.  Only
a ``(K, B) int32`` token block (plus the small carry vectors) ever crosses
back to the host.

Semantics are bitwise-aligned with the host loop: each scan step feeds the
previously sampled token through ``serve_step`` and samples from the
resulting logits, splitting the carried PRNG key exactly once per non-greedy
sample — the same (step, sample) sequence and the same key chain as the
``for t in range(gen_len)`` loop it replaces, so one seed reproduces the
same stream at any chunk size.  Rows that emit ``eos_id`` retire in-scan:
their later block entries hold ``pad_id`` and their cache rows freeze via
the same ``mask_cache_update`` active-mask discipline the engine uses for
parked slots.

Two flavors share one implementation, specialized by the ``pos`` rank:

* **static generate** — scalar ``pos`` (all rows at the same depth),
  advancing by 1 per step regardless of retirement, matching the host
  loop's shared position counter;
* **engine** — per-slot ``(B,)`` counters advancing only where a slot is
  active, matching the engine's per-slot bookkeeping.

Megasteps donate their cache argument (``donate_argnums``), so the decode
cache is updated in place instead of copied per dispatch; callers must
treat the passed-in cache as consumed (rebind to the returned one).  On a
serving mesh the donation preserves the PR-4 sharding constraints —
``serve_step`` re-constrains the cache every scan step, so input and output
buffers alias shard-for-shard.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.heads import LogitHead
from repro.api.sampler import Sampler, _sample_impl
from repro.models.config import ModelConfig


def jitted_megastep(cfg: ModelConfig, head: LogitHead, sampler: Sampler,
                    k: int, *, mesh=None, eos_id: Optional[int] = None,
                    pad_id: int = 0, masked: bool = False):
    """The jitted K-step decode megastep for one serving spec.

    Memoized on the full hashable spec ``(cfg, head, sampler, k, mesh,
    eos_id, pad_id, masked)`` — every engine tick and every ``generate()``
    chunk for the same spec dispatches one cached executable.

    Args:
      cfg: the model config.
      head: a bare ``LogitHead`` spec (``head.without_params()``); frozen
        arrays ride along per call as ``head_params``.
      sampler: the ``Sampler`` spec fused into the scan body.
      k: scan length — decode steps (= emitted tokens) per dispatch.
      mesh: optional serving mesh; threads the shard_map head path and the
        per-step cache sharding constraint through the scan.
      eos_id: with ``masked=True``, rows that emit it retire in-scan.
      pad_id: block filler for retired rows.
      masked: carry a ``(B,)`` active mask (engine slots / EOS retirement);
        ``False`` compiles the maskless fast path (static generate without
        ``eos_id``), bitwise-matching the host loop's unmasked steps.

    Returns:
      A jitted ``megastep(params, cache, last_tok, pos, key, *,
      head_params=None, active=None, encoder_states=None)`` returning
      ``(block, cache, last_tok, pos, active, key)`` with ``block`` a
      ``(k, B) int32`` token block.  The ``cache`` argument is **donated**.

    Raises:
      ValueError: on ``k < 1`` or ``eos_id`` without ``masked``.
    """
    if k < 1:
        raise ValueError(f"megastep needs k >= 1, got {k}")
    if eos_id is not None and not masked:
        raise ValueError("eos_id retirement needs masked=True")
    # Canonical all-positional key: lru_cache would otherwise key
    # keyword and positional spellings of the same spec separately.
    return _jitted_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id,
                            masked)


@functools.lru_cache(maxsize=None)
def _jitted_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id, masked):
    from repro.launch.steps import serve_step

    def megastep(params, cache, last_tok, pos, key, head_params=None,
                 active=None, encoder_states=None):
        def body(carry, _):
            cache, tok, pos, active, key = carry
            logits, cache = serve_step(
                params, cache, tok[:, None], pos, cfg,
                encoder_states=encoder_states, head=head,
                head_params=head_params,
                active=active if masked else None, mesh=mesh)
            # Same math as the host loop's jitted Sampler.sample — one key
            # split per non-greedy sample, none when greedy.
            key, nxt = _sample_impl(sampler, key, logits)
            if masked:
                nxt = jnp.where(active, nxt, jnp.int32(pad_id))
            if jnp.ndim(pos):       # per-slot counters: advance rows that
                                    # decoded this step (incl. an EOS step,
                                    # matching the host engine's += 1)
                pos = pos + (active.astype(jnp.int32) if masked else 1)
            else:                   # static generate: one shared depth
                pos = pos + 1
            if masked and eos_id is not None:
                active = active & (nxt != eos_id)
            return (cache, nxt, pos, active, key), nxt

        (cache, last_tok, pos, active, key), block = jax.lax.scan(
            body, (cache, last_tok, pos, active, key), None, length=k)
        return block, cache, last_tok, pos, active, key

    return jax.jit(megastep, donate_argnums=(1,))


def decode_chunks(params, cache, first_logits, *, cfg: ModelConfig,
                  head: LogitHead, sampler: Sampler, gen_len: int,
                  start_pos: int, chunk: int, eos_id: Optional[int] = None,
                  pad_id: int = 0, mesh=None, encoder_states=None):
    """The static-batch decode loop as on-device megasteps.

    Replaces ``generate()``'s per-token host loop for ``decode_chunk > 1``:
    the first token is sampled from the prefill logits (the same first key
    split as the host loop), then the remaining ``gen_len - 1`` steps run as
    ``chunk``-sized megasteps (plus one remainder-sized chunk).  When
    ``eos_id`` is set and every row retires, remaining chunks are skipped
    and the tail is padding — the host loop's early exit at chunk
    granularity.

    Args:
      params: backbone params.
      cache: the prefilled decode cache — **consumed** (donated to the
        first megastep); use the function's view of it only.
      first_logits: (B, V) last-position prefill logits.
      cfg / head / sampler / mesh / encoder_states: the serving spec, as in
        ``launch.serve.generate``.
      gen_len: total tokens to emit per row (including the first).
      start_pos: prompt length P (tokens already cached).
      chunk: megastep size K (>= 1).
      eos_id / pad_id: optional early-retirement token and filler.

    Returns:
      ``(tokens, stats)`` — (B, gen_len) int32 generated tokens (prompt
      excluded) and ``{"decode_steps": n}`` counting device decode steps.
    """
    b = first_logits.shape[0]
    key = sampler.init_key()
    key, tok0 = sampler.sample(key, first_logits)
    tok0 = tok0.astype(jnp.int32)
    masked = eos_id is not None
    active = (tok0 != eos_id) if masked else None
    spec = head.without_params()

    blocks = [tok0[:, None]]
    last_tok, pos = tok0, jnp.asarray(start_pos, jnp.int32)
    todo, steps = gen_len - 1, 0
    while todo > 0:
        k = min(chunk, todo)
        fn = jitted_megastep(cfg, spec, sampler, k, mesh=mesh,
                             eos_id=eos_id, pad_id=pad_id, masked=masked)
        block, cache, last_tok, pos, active, key = fn(
            params, cache, last_tok, pos, key, head_params=head.params,
            active=active, encoder_states=encoder_states)
        blocks.append(block.T)
        steps += k
        todo -= k
        if masked and todo > 0 and not bool(jax.device_get(active.any())):
            blocks.append(jnp.full((b, todo), pad_id, jnp.int32))
            break
    return jnp.concatenate(blocks, axis=1), {"decode_steps": steps}
