"""On-device decode megasteps: K tokens per dispatch (DESIGN.md §10).

The per-token serving loop pays three host costs the paper's cheap sketched
step cannot amortize: a Python-level jit dispatch per token, a device→host
sync to sample (the old ``np.asarray`` in the loop), and — without buffer
donation — a full decode-cache copy per step.  This module moves the loop
onto the device: one jitted **megastep** runs K decode steps as a
``jax.lax.scan`` whose carry is ``(cache, last_tok, pos, active, key)``,
with the :class:`repro.api.Sampler` (temperature / top-k / top-p, split-key
chain) and EOS → active-mask retirement fused *inside* the scan body.  Only
a ``(K, B) int32`` token block (plus the small carry vectors) ever crosses
back to the host.

Semantics are bitwise-aligned with the host loop: each scan step feeds the
previously sampled token through ``serve_step`` and samples from the
resulting logits, splitting the carried PRNG key exactly once per non-greedy
sample — the same (step, sample) sequence and the same key chain as the
``for t in range(gen_len)`` loop it replaces, so one seed reproduces the
same stream at any chunk size.  Rows that emit ``eos_id`` retire in-scan:
their later block entries hold ``pad_id`` and their cache rows freeze via
the same ``mask_cache_update`` active-mask discipline the engine uses for
parked slots.

Two flavors share one implementation, specialized by the ``pos`` rank:

* **static generate** — scalar ``pos`` (all rows at the same depth),
  advancing by 1 per step regardless of retirement, matching the host
  loop's shared position counter;
* **engine** — per-slot ``(B,)`` counters advancing only where a slot is
  active, matching the engine's per-slot bookkeeping.

Megasteps donate their cache argument (``donate_argnums``), so the decode
cache is updated in place instead of copied per dispatch; callers must
treat the passed-in cache as consumed (rebind to the returned one).  On a
serving mesh the donation preserves the PR-4 sharding constraints —
``serve_step`` re-constrains the cache every scan step, so input and output
buffers alias shard-for-shard.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.heads import LogitHead
from repro.api.sampler import Sampler, _sample_impl
from repro.models.config import ModelConfig


def jitted_megastep(cfg: ModelConfig, head: LogitHead, sampler: Sampler,
                    k: int, *, mesh=None, eos_id: Optional[int] = None,
                    pad_id: int = 0, masked: bool = False):
    """The jitted K-step decode megastep for one serving spec.

    Memoized on the full hashable spec ``(cfg, head, sampler, k, mesh,
    eos_id, pad_id, masked)`` — every engine tick and every ``generate()``
    chunk for the same spec dispatches one cached executable.

    Args:
      cfg: the model config.
      head: a bare ``LogitHead`` spec (``head.without_params()``); frozen
        arrays ride along per call as ``head_params``.
      sampler: the ``Sampler`` spec fused into the scan body.
      k: scan length — decode steps (= emitted tokens) per dispatch.
      mesh: optional serving mesh; threads the shard_map head path and the
        per-step cache sharding constraint through the scan.
      eos_id: with ``masked=True``, rows that emit it retire in-scan.
      pad_id: block filler for retired rows.
      masked: carry a ``(B,)`` active mask (engine slots / EOS retirement);
        ``False`` compiles the maskless fast path (static generate without
        ``eos_id``), bitwise-matching the host loop's unmasked steps.

    Returns:
      A jitted ``megastep(params, cache, last_tok, pos, key, *,
      head_params=None, active=None, encoder_states=None)`` returning
      ``(block, cache, last_tok, pos, active, key)`` with ``block`` a
      ``(k, B) int32`` token block.  The ``cache`` argument is **donated**.

    Raises:
      ValueError: on ``k < 1`` or ``eos_id`` without ``masked``.
    """
    if k < 1:
        raise ValueError(f"megastep needs k >= 1, got {k}")
    if eos_id is not None and not masked:
        raise ValueError("eos_id retirement needs masked=True")
    # Canonical all-positional key: lru_cache would otherwise key
    # keyword and positional spellings of the same spec separately.
    return _jitted_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id,
                            masked)


@functools.lru_cache(maxsize=None)
def _jitted_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id, masked):
    from repro.launch.steps import serve_step

    def megastep(params, cache, last_tok, pos, key, head_params=None,
                 active=None, encoder_states=None):
        def body(carry, _):
            cache, tok, pos, active, key = carry
            logits, cache = serve_step(
                params, cache, tok[:, None], pos, cfg,
                encoder_states=encoder_states, head=head,
                head_params=head_params,
                active=active if masked else None, mesh=mesh)
            # Same math as the host loop's jitted Sampler.sample — one key
            # split per non-greedy sample, none when greedy.
            key, nxt = _sample_impl(sampler, key, logits)
            if masked:
                nxt = jnp.where(active, nxt, jnp.int32(pad_id))
            if jnp.ndim(pos):       # per-slot counters: advance rows that
                                    # decoded this step (incl. an EOS step,
                                    # matching the host engine's += 1)
                pos = pos + (active.astype(jnp.int32) if masked else 1)
            else:                   # static generate: one shared depth
                pos = pos + 1
            if masked and eos_id is not None:
                active = active & (nxt != eos_id)
            return (cache, nxt, pos, active, key), nxt

        (cache, last_tok, pos, active, key), block = jax.lax.scan(
            body, (cache, last_tok, pos, active, key), None, length=k)
        return block, cache, last_tok, pos, active, key

    return jax.jit(megastep, donate_argnums=(1,))


def jitted_spec_megastep(cfg: ModelConfig, head: LogitHead, sampler: Sampler,
                         k: int, *, mesh=None, eos_id: Optional[int] = None,
                         pad_id: int = 0, masked: bool = False):
    """The jitted speculative two-head megastep (DESIGN.md §11).

    ``head`` (normally the cheap sketch head) **drafts** ``k`` tokens
    through the backbone inside a ``lax.scan``, recording each step's final
    hidden, its pre-sample PRNG key, and a rollback snapshot of the
    non-positional cache state.  One batched **dense verify** pass —
    ``dense_verify_logits`` over the stacked hiddens, no extra backbone
    work — then replays the sampler on the recorded keys, producing the
    token pure dense decode would have drawn at every position.  Acceptance
    is *common-random-numbers rejection sampling*: a draft survives iff it
    equals the dense draw under the very randomness dense decode would have
    used, and the emitted block is always the dense draws themselves — so
    the output stream is **bitwise identical** to dense decode regardless
    of how good the draft head is; the draft only sets how many of the
    ``k`` backbone steps commit per dispatch.

    Rows commit in lockstep at ``m = min`` over active rows of
    ``min(accepted + 1, k)`` (the ``+1`` is the free bonus/correction
    token, whose verify logits are conditioned only on the matched prefix).
    The carry rewinds to the committed step: positional KV/MLA caches by
    the position counter alone, ring/recurrent layers from the recorded
    snapshots (``cache_rollback``), and the PRNG key to the post-sample key
    of step ``m - 1`` — exactly the state dense decode would hold after
    ``m`` tokens.  EOS retirement inside the committed block mirrors
    ``jitted_megastep``: later entries pad, cache rows freeze.

    Memoized on the full hashable spec like ``jitted_megastep``.

    Returns:
      A jitted ``spec_megastep(params, cache, last_tok, pos, key, *,
      head_params=None, active=None, encoder_states=None)`` returning
      ``(block, m, acc, adv, cache, last_tok, pos, active, key)`` where
      ``block`` is the (k, B) int32 verify-token block of which only rows
      ``< m`` are committed, ``acc`` (B,) counts committed accepted draft
      tokens (for acceptance-rate stats) and ``adv`` (B,) the tokens each
      row actually emitted (≤ m; less only past an in-block EOS).  The
      ``cache`` argument is **donated**.

    Raises:
      ValueError: on ``k < 1``, ``eos_id`` without ``masked``, or a
        ``DenseHead``-style spec without its own logits path when greedy
        drafting is impossible (any LogitHead works; no check needed).
    """
    if k < 1:
        raise ValueError(f"spec megastep needs k >= 1, got {k}")
    if eos_id is not None and not masked:
        raise ValueError("eos_id retirement needs masked=True")
    return _jitted_spec_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id,
                                 masked)


@functools.lru_cache(maxsize=None)
def _jitted_spec_megastep(cfg, head, sampler, k, mesh, eos_id, pad_id,
                          masked):
    from repro.launch.steps import serve_step
    from repro.models.model import (cache_rollback, cache_snapshot,
                                    dense_verify_logits)

    def spec_megastep(params, cache, last_tok, pos, key, head_params=None,
                      active=None, encoder_states=None):
        pos_in = pos

        # ---- draft: k cheap-head steps through the backbone -------------
        # `active` is a closure constant for the whole draft (no carry):
        # EOS can only be declared by the verify tokens, after the scan.
        def draft_body(carry, _):
            cache, tok, pos, key = carry
            logits, cache, hidden = serve_step(
                params, cache, tok[:, None], pos, cfg,
                encoder_states=encoder_states, head=head,
                head_params=head_params,
                active=active if masked else None, mesh=mesh,
                return_hidden=True)
            pre_key = key
            key, nxt = _sample_impl(sampler, key, logits)
            if masked:
                nxt = jnp.where(active, nxt, jnp.int32(pad_id))
            if jnp.ndim(pos):
                pos = pos + (active.astype(jnp.int32) if masked else 1)
            else:
                pos = pos + 1
            return ((cache, nxt, pos, key),
                    (hidden, pre_key, key, nxt, cache_snapshot(cfg, cache)))

        (cache, _, _, _), (hiddens, pre_keys, post_keys, drafts, snaps) = \
            jax.lax.scan(draft_body, (cache, last_tok, pos_in, key), None,
                         length=k)

        # ---- verify: ONE batched dense pass over the k hiddens ----------
        # (B, k, d) layout so the sharding constraint inside
        # dense_verify_logits sees forward()'s exact (B, S, V) axes — the
        # partitioner must not treat the verify einsum differently from
        # the in-forward unembed it must match bitwise.
        dense = dense_verify_logits(params, jnp.swapaxes(hiddens, 0, 1), cfg)
        dense = jnp.swapaxes(dense, 0, 1)                   # (k, B, V)

        if sampler.is_greedy:
            verify = jnp.argmax(dense, axis=-1).astype(jnp.int32)
        else:
            # Replay the sampler on the recorded pre-sample keys: at every
            # position the committed prefix equals dense decode's, so the
            # key chain — and hence the categorical draw — is the same.
            def verify_body(_, xs):
                pre_key, logits = xs
                _, tok = _sample_impl(sampler, pre_key, logits)
                return (), tok

            _, verify = jax.lax.scan(verify_body, (), (pre_keys, dense))
        if masked:
            verify = jnp.where(active[None, :], verify, jnp.int32(pad_id))

        # ---- acceptance: longest matching prefix + bonus token ----------
        match = (drafts == verify).astype(jnp.int32)        # (k, B)
        a = jnp.cumprod(match, axis=0).sum(0)               # leading matches
        n = jnp.minimum(a + 1, k)                           # + bonus, capped
        if masked:
            n = jnp.where(active, n, k)   # parked rows don't constrain m
        m = n.min()                       # lockstep commit (global key chain)

        # ---- emission bookkeeping (mirrors jitted_megastep's EOS path) --
        steps = jnp.arange(k)[:, None]                      # (k, 1)
        if masked:
            hits = ((verify == eos_id) if eos_id is not None
                    else jnp.zeros(verify.shape, bool))
            prior = jnp.cumsum(hits.astype(jnp.int32), axis=0) \
                - hits.astype(jnp.int32)                    # EOS before i
            alive = active[None, :] & (prior == 0)
        else:
            alive = jnp.ones(verify.shape, bool)
        committed = alive & (steps < m)
        block = jnp.where(committed, verify, jnp.int32(pad_id))
        adv = committed.astype(jnp.int32).sum(0)            # emitted per row
        acc = jnp.minimum(a, adv)                           # accepted drafts
        if masked and eos_id is not None:
            active = active & ~(hits & (steps < m)).any(0)

        # ---- rewind the carry to the committed step ---------------------
        # Cache: positional layers keep the draft-final buffers (their
        # stale writes sit beyond the rewound position counter); ring and
        # recurrent layers take the snapshot recorded after draft step
        # m - 1 — whose processed inputs (last_tok, drafts[:m-1]) all
        # matched the committed stream, because m - 1 <= accepted count.
        sel = lambda s: jax.lax.dynamic_index_in_dim(s, m - 1, 0,
                                                     keepdims=False)
        cache = cache_rollback(cfg, cache, jax.tree.map(sel, snaps))
        last_tok = sel(block)
        key = sel(post_keys)              # dense decode's key after m draws
        pos = pos_in + (adv if jnp.ndim(pos_in) else m)
        return block, m, acc, adv, cache, last_tok, pos, active, key

    return jax.jit(spec_megastep, donate_argnums=(1,))


def spec_decode_chunks(params, cache, first_logits, *, cfg: ModelConfig,
                       head: LogitHead, sampler: Sampler, gen_len: int,
                       start_pos: int, spec_k: int,
                       eos_id: Optional[int] = None, pad_id: int = 0,
                       mesh=None, encoder_states=None):
    """The static-batch speculative decode loop (``generate(spec_decode=K)``).

    Mirrors :func:`decode_chunks`: the first token comes from the prefill
    logits — which are always *dense* logits, so the stream starts on the
    dense chain — then each iteration dispatches one
    :func:`jitted_spec_megastep` and commits its ``m`` verified tokens.
    ``m`` is data-dependent, so the loop syncs one scalar per dispatch (the
    same cost class as the engine's per-tick retirement sync).

    Returns ``(tokens, stats)`` with stats counting backbone draft steps
    (``decode_steps``), ``verify_calls``, ``draft_tokens`` and
    ``accepted_draft_tokens`` — acceptance rate is
    ``accepted_draft_tokens / draft_tokens``.
    """
    b = first_logits.shape[0]
    key = sampler.init_key()
    key, tok0 = sampler.sample(key, first_logits)
    tok0 = tok0.astype(jnp.int32)
    masked = eos_id is not None
    active = (tok0 != eos_id) if masked else None
    spec = head.without_params()

    blocks = [tok0[:, None]]
    last_tok, pos = tok0, jnp.asarray(start_pos, jnp.int32)
    todo = gen_len - 1
    stats = {"decode_steps": 0, "verify_calls": 0, "draft_tokens": 0,
             "accepted_draft_tokens": 0}
    while todo > 0:
        kk = min(spec_k, todo)
        fn = jitted_spec_megastep(cfg, spec, sampler, kk, mesh=mesh,
                                  eos_id=eos_id, pad_id=pad_id,
                                  masked=masked)
        block, m, acc, adv, cache, last_tok, pos, active, key = fn(
            params, cache, last_tok, pos, key, head_params=head.params,
            active=active, encoder_states=encoder_states)
        m = int(jax.device_get(m))
        blocks.append(jnp.asarray(block[:m]).T)
        stats["decode_steps"] += kk
        stats["verify_calls"] += 1
        stats["draft_tokens"] += kk * b
        stats["accepted_draft_tokens"] += int(jax.device_get(acc.sum()))
        todo -= m
        if masked and todo > 0 and not bool(jax.device_get(active.any())):
            blocks.append(jnp.full((b, todo), pad_id, jnp.int32))
            break
    return jnp.concatenate(blocks, axis=1), stats


def decode_chunks(params, cache, first_logits, *, cfg: ModelConfig,
                  head: LogitHead, sampler: Sampler, gen_len: int,
                  start_pos: int, chunk: int, eos_id: Optional[int] = None,
                  pad_id: int = 0, mesh=None, encoder_states=None):
    """The static-batch decode loop as on-device megasteps.

    Replaces ``generate()``'s per-token host loop for ``decode_chunk > 1``:
    the first token is sampled from the prefill logits (the same first key
    split as the host loop), then the remaining ``gen_len - 1`` steps run as
    ``chunk``-sized megasteps (plus one remainder-sized chunk).  When
    ``eos_id`` is set and every row retires, remaining chunks are skipped
    and the tail is padding — the host loop's early exit at chunk
    granularity.

    Args:
      params: backbone params.
      cache: the prefilled decode cache — **consumed** (donated to the
        first megastep); use the function's view of it only.
      first_logits: (B, V) last-position prefill logits.
      cfg / head / sampler / mesh / encoder_states: the serving spec, as in
        ``launch.serve.generate``.
      gen_len: total tokens to emit per row (including the first).
      start_pos: prompt length P (tokens already cached).
      chunk: megastep size K (>= 1).
      eos_id / pad_id: optional early-retirement token and filler.

    Returns:
      ``(tokens, stats)`` — (B, gen_len) int32 generated tokens (prompt
      excluded) and ``{"decode_steps": n}`` counting device decode steps.
    """
    b = first_logits.shape[0]
    key = sampler.init_key()
    key, tok0 = sampler.sample(key, first_logits)
    tok0 = tok0.astype(jnp.int32)
    masked = eos_id is not None
    active = (tok0 != eos_id) if masked else None
    spec = head.without_params()

    blocks = [tok0[:, None]]
    last_tok, pos = tok0, jnp.asarray(start_pos, jnp.int32)
    todo, steps = gen_len - 1, 0
    while todo > 0:
        k = min(chunk, todo)
        fn = jitted_megastep(cfg, spec, sampler, k, mesh=mesh,
                             eos_id=eos_id, pad_id=pad_id, masked=masked)
        block, cache, last_tok, pos, active, key = fn(
            params, cache, last_tok, pos, key, head_params=head.params,
            active=active, encoder_states=encoder_states)
        blocks.append(block.T)
        steps += k
        todo -= k
        if masked and todo > 0 and not bool(jax.device_get(active.any())):
            blocks.append(jnp.full((b, todo), pad_id, jnp.int32))
            break
    return jnp.concatenate(blocks, axis=1), {"decode_steps": steps}
