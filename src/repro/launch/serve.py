"""Serving launcher: static batch or continuous-batching engine, optional
sketched head.

Two serving modes over a (smoke-scale on CPU) model:

* **static** (default) — one synthetic request batch: a single bulk prefill
  ingests every prompt into the decode cache, then the decode loop emits
  tokens step by step until the *slowest* request is done.
* **``--engine``** — the continuous-batching engine (repro.launch.engine,
  DESIGN.md §7): a pool of ``--batch`` cache slots served from a FIFO queue
  with staggered arrivals and skewed per-request generation lengths;
  finished sequences retire individually and their slots are recycled
  mid-decode.

``--sketch-head`` swaps the dense logit matmul for the Representer-Sketch
head (the paper's technique as a first-class serving feature — DESIGN.md §4)
in either mode: the backbone returns the final hidden and the frozen
(L, R, V) sketch produces the logits in one fused Pallas call
(repro.kernels.fused_decode).  The head is distilled offline by
examples/serve_sketch_head.py and loaded via ``--head-path``; without a
saved head a quick in-process distillation builds one.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--sketch-head] [--no-fused] \
      [--engine --requests 8 --arrival-every 2]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import jitted_serve_fns
from repro.models.config import SketchHeadConfig
from repro.models.model import init_decode_cache, init_model


def generate(params, cfg, prompts: jnp.ndarray, gen_len: int,
             encoder_states=None, sketch_head_params=None,
             sketch_cfg: SketchHeadConfig | None = None,
             fused: bool = True, greedy: bool = True, seed: int = 0):
    """Bulk prefill + decode. prompts: (B, P) → tokens (B, P+gen_len).

    Sampling (``greedy=False``) threads a split key chain from a single
    ``seed``: runs with the same seed reproduce exactly, different seeds
    give independent streams.  (Rebuilding ``PRNGKey(t)`` from the step
    index — the old behavior — reused one fixed stream for every run.)
    """
    b, p = prompts.shape
    max_seq = p + gen_len
    cache = init_decode_cache(cfg, b, max_seq)

    # Jitted steps are memoized per (cfg, head, fused) — repeated generate()
    # calls (static-batch chunking, benchmarks) reuse one compile cache.
    prefill, step, _, _ = jitted_serve_fns(cfg, sketch_cfg, fused)

    # Bulk prefill: the whole prompt runs in one forward pass that fills the
    # decode cache, replacing the P per-token decode steps of the old loop.
    # Long prompts stay memory-bounded: cached attention switches to the
    # online-softmax chunked path above the same thresholds as training.
    logits, cache = prefill(params, prompts, encoder_states=encoder_states,
                            cache=cache)

    # Decode: with a sketch head the step skips the dense unembed and
    # produces logits from the frozen sketch (fused kernel by default).
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    for t in range(gen_len):
        if greedy:
            nxt = jnp.argmax(logits, -1)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits)
        nxt = nxt[:, None].astype(jnp.int32)
        out.append(nxt)
        if t == gen_len - 1:
            break  # the last token needs no forward — its logits are unused
        logits, cache = step(params, cache, nxt,
                             jnp.asarray(p + t, jnp.int32),
                             encoder_states=encoder_states,
                             sketch_head=sketch_head_params)
    return jnp.concatenate(out, axis=1)


def build_or_load_head(params, cfg, head_path: str | None,
                       distill_steps: int = 300):
    """Load a frozen sketch head, or distill one from the dense head now.

    The offline path (examples/serve_sketch_head.py) distills at a real
    budget and saves with ``save_head``; this fallback runs a short
    distillation so ``--sketch-head`` is self-contained at smoke scale.
    """
    from repro.core.distill import DistillConfig
    from repro.core.sketch_lm_head import (distill_head, freeze_head,
                                           load_head)

    if head_path:
        if not Path(head_path).exists():
            raise FileNotFoundError(
                f"--head-path {head_path} does not exist; run "
                f"examples/serve_sketch_head.py to distill and save a head, "
                f"or drop --head-path to distill one in-process")
        head, head_cfg = load_head(head_path)
        l, r, v = head["array"].shape
        d = head["proj"].shape[0]
        if v != cfg.vocab_size or d != cfg.d_model:
            raise ValueError(
                f"sketch head {head_path} was frozen for (d_model={d}, "
                f"vocab={v}) but --arch {cfg.name} has "
                f"(d_model={cfg.d_model}, vocab={cfg.vocab_size})")
        print(f"loaded sketch head from {head_path} "
              f"(L={head_cfg.n_rows}, R={head_cfg.n_buckets})")
        return head, head_cfg

    head_cfg = cfg.sketch_head or SketchHeadConfig(
        n_rows=128, n_buckets=16, k=1, proj_dim=32, bandwidth=2.0)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    hiddens = jax.random.normal(jax.random.PRNGKey(11),
                                (1024, cfg.d_model))
    print(f"distilling sketch head (L={head_cfg.n_rows}, "
          f"R={head_cfg.n_buckets}, {distill_steps} steps) …")
    kparams, metrics = distill_head(
        jax.random.PRNGKey(12), table, hiddens, head_cfg, n_points=256,
        distill_cfg=DistillConfig(n_steps=distill_steps, lr=5e-3))
    print(f"  distill MSE: {metrics['final_mse']:.5f}")
    return freeze_head(jax.random.PRNGKey(13), kparams, head_cfg), head_cfg


def run_engine(params, cfg, args, sketch_head, sketch_cfg) -> None:
    """Serve a synthetic request stream through the continuous-batching
    engine: staggered arrivals, skewed generation lengths, recycled slots."""
    from repro.launch.engine import make_engine

    n_requests = args.requests or 2 * args.batch
    max_seq = args.prompt_len + args.gen
    engine = make_engine(params, cfg, n_slots=args.batch, max_seq=max_seq,
                         sketch_head=sketch_head, sketch_cfg=sketch_cfg,
                         fused=not args.no_fused, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size, args.prompt_len,
                              dtype=np.int32)
        # Skewed length mix: even requests are short, odd run the full --gen.
        gen = args.gen if i % 2 else max(1, args.gen // 4)
        engine.submit(prompt, gen, arrival=i * args.arrival_every)

    t0 = time.time()
    finished = engine.run()
    dur = time.time() - t0
    n_generated = sum(len(v) for v in finished.values())
    head_kind = ("sketch/fused" if sketch_head is not None and not args.no_fused
                 else "sketch/2-kernel" if sketch_head is not None
                 else "dense")
    print(f"arch={cfg.name} head={head_kind} engine served "
          f"{len(finished)} requests over {args.batch} slots: "
          f"{n_generated} tokens in {dur:.1f}s "
          f"({n_generated / dur:.1f} tok/s incl. compile), "
          f"{engine.stats['decode_steps']} decode steps, "
          f"slot utilization {engine.slot_utilization:.2f}")
    first = finished[min(finished)]
    print("sample token ids:", np.asarray(first[:24]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / engine slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16,
                    help="generation length (engine: per-request max; the "
                         "synthetic mix skews between gen//4 and gen)")
    ap.add_argument("--sketch-head", action="store_true",
                    help="decode with the Representer-Sketch head instead "
                         "of the dense logit matmul")
    ap.add_argument("--head-path", default=None,
                    help="frozen head .npz from examples/serve_sketch_head.py")
    ap.add_argument("--no-fused", action="store_true",
                    help="use the two-kernel (lsh_hash + sketch_head) decode "
                         "path instead of the fused kernel")
    ap.add_argument("--engine", action="store_true",
                    help="serve a request stream through the "
                         "continuous-batching engine instead of one static "
                         "batch")
    ap.add_argument("--requests", type=int, default=0,
                    help="engine mode: number of requests (default 2×batch)")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="engine mode: ticks between request arrivals")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling / request-stream seed")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sketch_head = sketch_cfg = None
    if args.sketch_head:
        sketch_head, sketch_cfg = build_or_load_head(params, cfg,
                                                     args.head_path)

    if args.engine:
        run_engine(params, cfg, args, sketch_head, sketch_cfg)
        return

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.n_encoder_tokens:
        enc = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_encoder_tokens, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, encoder_states=enc,
                   sketch_head_params=sketch_head, sketch_cfg=sketch_cfg,
                   fused=not args.no_fused, seed=args.seed)
    dur = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    head_kind = ("sketch/fused" if sketch_head is not None and not args.no_fused
                 else "sketch/2-kernel" if sketch_head is not None
                 else "dense")
    print(f"arch={cfg.name} head={head_kind} served {args.batch} seqs, "
          f"{total_tokens} tokens in {dur:.1f}s "
          f"({total_tokens / dur:.1f} tok/s incl. compile)")
    print("sample token ids:", np.asarray(out[0, :24]))


if __name__ == "__main__":
    main()
