"""Batched serving launcher: prefill + decode loop, optional sketched head.

Serves a (smoke-scale on CPU) model over synthetic request batches:
prefill ingests each request's prompt, then the decode loop emits tokens
step by step from the KV/state cache.  ``--sketch-head`` swaps the dense
logit matmul for the Representer-Sketch head (the paper's technique as a
first-class serving feature — see DESIGN.md §4): the head is distilled
offline by examples/serve_sketch_head.py and loaded here.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import prefill_step, serve_step
from repro.models.model import forward, init_decode_cache, init_model


def generate(params, cfg, prompts: jnp.ndarray, gen_len: int,
             encoder_states=None, sketch_head_params=None,
             greedy: bool = True):
    """Prefill + decode. prompts: (B, P) → tokens (B, P+gen_len)."""
    b, p = prompts.shape
    max_seq = p + gen_len
    cache = init_decode_cache(cfg, b, max_seq)

    # Prefill via per-token decode steps keeps one compiled step function
    # (production would lower a bulk prefill; steps.prefill_step covers that
    # path and the 32k dry-run cells exercise it at scale).
    step = jax.jit(functools.partial(serve_step, cfg=cfg))

    toks = prompts
    logits = None
    for t in range(p):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.asarray(t, jnp.int32),
                             encoder_states=encoder_states)

    out = [toks]
    for t in range(gen_len):
        if sketch_head_params is not None:
            # logits from the sketched head are produced inside serve path
            pass
        nxt = (jnp.argmax(logits, -1) if greedy
               else jax.random.categorical(jax.random.PRNGKey(t), logits))
        nxt = nxt[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, cache = step(params, cache, nxt,
                             jnp.asarray(p + t, jnp.int32),
                             encoder_states=encoder_states)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.n_encoder_tokens:
        enc = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_encoder_tokens, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.gen, encoder_states=enc)
    dur = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"arch={cfg.name} served {args.batch} seqs, "
          f"{total_tokens} tokens in {dur:.1f}s "
          f"({total_tokens / dur:.1f} tok/s incl. compile)")
    print("sample token ids:", np.asarray(out[0, :24]))


if __name__ == "__main__":
    main()
