"""Serving launcher: static batch or continuous-batching engine, any head.

Two serving modes over a (smoke-scale on CPU) model, both routed through the
``repro.api`` facade (``LM`` + ``LogitHead`` + ``Sampler`` — DESIGN.md §8):

* **static** (default) — one synthetic request batch: a single bulk prefill
  ingests every prompt into the decode cache, then the decode loop emits
  tokens step by step until the *slowest* request is done.
* **``--engine``** — the continuous-batching engine (repro.launch.engine,
  DESIGN.md §7): a pool of ``--batch`` cache slots served from a FIFO queue
  with staggered arrivals and skewed per-request generation lengths;
  finished sequences retire individually and their slots are recycled
  mid-decode.  ``--paged --page-size N`` swaps the contiguous slot pool for
  the paged pool + exact-prompt prefix cache (DESIGN.md §13): bitwise the
  same streams, repeated prompts prefill once.  ``--stats-json`` appends
  the engine stats dict as one parseable ``STATS_JSON {…}`` line.

``--sketch-head`` swaps the dense logit matmul for the Representer-Sketch
head (the paper's technique as a first-class serving feature — DESIGN.md §4)
in either mode; ``--backend fused|two_kernel|ref`` picks its decode path
(one fused Pallas call by default).  The head is distilled offline by
examples/serve_sketch_head.py and loaded via ``--head-path``; without a
saved head a quick in-process distillation builds one.  ``--quant
int8|int4`` serves the head from quantized count-array storage (per-row
symmetric scales, dequantized in-register by the decode kernels —
DESIGN.md §12); a ``--head-path`` archive saved quantized loads as-is.

``--mesh <data>x<model>`` serves SPMD over a device mesh in either mode
(params via ``sharding/rules.py``, caches batch-sharded over ``data``,
sketch count arrays over ``model`` with one psum per decode step —
DESIGN.md §9); on CPU force devices first with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--decode-chunk K`` moves the decode loop on-device in either mode: K
steps per dispatch as one ``lax.scan`` megastep with sampling and EOS
retirement fused in (launch/decode_loop.py, DESIGN.md §10) — ~1/K the
host syncs, with token streams bitwise K-invariant (static mode always;
engine mode except seeded sampling when a mid-chunk EOS shifts a
re-admission — docs/serving.md).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--sketch-head] [--backend fused] \
      [--quant int8] \
      [--temperature 0.8 --top-k 40 --top-p 0.95] [--decode-chunk 8] \
      [--engine --requests 8 --arrival-every 2] [--mesh 4x2]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.heads import DenseHead, LogitHead, SketchHead
from repro.api.sampler import Sampler
from repro.configs import get_config
from repro.launch.steps import jitted_serve_fns
from repro.models.config import SketchHeadConfig
from repro.models.model import init_decode_cache, init_model


def generate(params, cfg, prompts: jnp.ndarray, gen_len: int,
             encoder_states=None, *, head: Optional[LogitHead] = None,
             sampler: Optional[Sampler] = None,
             eos_id: Optional[int] = None, pad_id: int = 0,
             return_stats: bool = False, mesh=None, decode_chunk: int = 1,
             spec_decode: int = 0, sketch_head_params=None,
             sketch_cfg: Optional[SketchHeadConfig] = None,
             fused=None, greedy=None, seed=None):
    """Bulk prefill + decode. prompts: (B, P) → tokens (B, P+gen_len).

    ``head`` (a repro.api ``LogitHead``, dense by default) produces the
    per-step logits; ``sampler`` (greedy by default) picks the tokens,
    threading a split key chain from its seed so runs with the same sampler
    reproduce exactly.  With ``eos_id``, a sequence that emits it is
    finished: its later positions hold ``pad_id``, its cache row freezes
    (the engine's parked-slot discipline), and the loop exits early once
    every row is done — finished sequences stop counting toward decode
    work.  ``return_stats=True`` additionally returns ``{"decode_steps"}``.

    ``decode_chunk=K`` (> 1) runs the decode loop on device: sampling and
    EOS retirement fuse into K-step ``lax.scan`` megasteps
    (launch/decode_loop.py, DESIGN.md §10) so only token blocks cross to
    host — same streams, 1/K the host syncs and dispatches.  The default
    ``decode_chunk=1`` keeps the per-token host loop (the bitwise-parity
    reference the megastep is tested against).

    ``spec_decode=K`` (> 0; mutually exclusive with ``decode_chunk > 1``)
    decodes speculatively: ``head`` drafts K tokens per dispatch and one
    batched dense pass verifies them (launch/decode_loop.py, DESIGN.md
    §11).  The emitted stream is bitwise-identical to dense decode with the
    same ``sampler`` — the head only sets how many drafts commit per
    verify; stats gain ``verify_calls`` / ``draft_tokens`` /
    ``accepted_draft_tokens``.

    ``mesh`` serves SPMD over a ``(data, model)`` device mesh: params and
    head arrays are placed per ``sharding/rules.py`` (a no-op when the LM
    facade already placed them), the decode cache batch-shards over
    ``data``, and sketch heads decode on their shard_map path
    (DESIGN.md §9).

    The pre-redesign ``sketch_head_params=/sketch_cfg=/fused=/greedy=/
    seed=`` kwargs keep working behind a DeprecationWarning.
    """
    from repro.launch.steps import resolve_legacy_serving_kwargs
    head, sampler = resolve_legacy_serving_kwargs(
        head, sampler, sketch_head_params, sketch_cfg, fused, greedy, seed,
        "generate()")
    head = head or DenseHead()
    sampler = sampler or Sampler()
    if decode_chunk < 1:
        raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
    if spec_decode < 0:
        raise ValueError(f"spec_decode must be >= 0, got {spec_decode}")
    if spec_decode and decode_chunk > 1:
        raise ValueError("spec_decode and decode_chunk > 1 are mutually "
                         "exclusive: the speculative megastep already "
                         "advances up to K tokens per dispatch")
    b, p = prompts.shape
    max_seq = p + gen_len
    cache = init_decode_cache(cfg, b, max_seq)
    if mesh is not None:
        from repro.launch.mesh import place_serving_state
        from repro.sharding.rules import cache_shardings
        params, head = place_serving_state(params, head, mesh)
        cache = jax.device_put(cache, cache_shardings(cache, mesh))

    # Jitted steps are memoized per (cfg, head spec, mesh) — repeated
    # generate() calls (static-batch chunking, benchmarks) reuse one
    # compile cache.
    prefill, step, _, _ = jitted_serve_fns(cfg, head.without_params(),
                                           mesh=mesh)

    # Bulk prefill: the whole prompt runs in one forward pass that fills the
    # decode cache, replacing the P per-token decode steps of the old loop.
    # Long prompts stay memory-bounded: cached attention switches to the
    # online-softmax chunked path above the same thresholds as training.
    logits, cache = prefill(params, prompts, encoder_states=encoder_states,
                            cache=cache)

    if spec_decode:
        from repro.launch.decode_loop import spec_decode_chunks
        tail, stats = spec_decode_chunks(
            params, cache, logits, cfg=cfg, head=head, sampler=sampler,
            gen_len=gen_len, start_pos=p, spec_k=spec_decode, eos_id=eos_id,
            pad_id=pad_id, mesh=mesh, encoder_states=encoder_states)
        tokens = jnp.concatenate([prompts.astype(jnp.int32), tail], axis=1)
        return (tokens, stats) if return_stats else tokens

    if decode_chunk > 1:
        from repro.launch.decode_loop import decode_chunks
        tail, stats = decode_chunks(
            params, cache, logits, cfg=cfg, head=head, sampler=sampler,
            gen_len=gen_len, start_pos=p, chunk=decode_chunk, eos_id=eos_id,
            pad_id=pad_id, mesh=mesh, encoder_states=encoder_states)
        tokens = jnp.concatenate([prompts.astype(jnp.int32), tail], axis=1)
        return (tokens, stats) if return_stats else tokens

    key = sampler.init_key()
    out = [prompts]
    finished = np.zeros(b, bool)
    stats = {"decode_steps": 0}
    for t in range(gen_len):
        key, nxt = sampler.sample(key, logits)
        if eos_id is not None:
            # EOS bookkeeping needs host values; without eos_id the tokens
            # stay on device so dispatch pipelines across steps.
            nxt_h = np.where(finished, pad_id,
                             np.asarray(nxt, np.int32)).astype(np.int32)
            finished |= nxt_h == eos_id
            nxt = jnp.asarray(nxt_h)
        nxt = nxt[:, None].astype(jnp.int32)
        out.append(nxt)
        if t == gen_len - 1:
            break  # the last token needs no forward — its logits are unused
        if eos_id is not None and finished.all():
            # Early stop: every sequence is done; the rest is padding.
            out.append(jnp.full((b, gen_len - 1 - t), pad_id, jnp.int32))
            break
        active = jnp.asarray(~finished) if eos_id is not None else None
        logits, cache = step(params, cache, nxt,
                             jnp.asarray(p + t, jnp.int32),
                             encoder_states=encoder_states,
                             head_params=head.params, active=active)
        stats["decode_steps"] += 1
    tokens = jnp.concatenate(out, axis=1)
    return (tokens, stats) if return_stats else tokens


def build_or_load_head(params, cfg, head_path: str | None,
                       backend: str | None = None,
                       distill_steps: int = 300,
                       quant: str | None = None) -> SketchHead:
    """Load a frozen sketch head, or distill one from the dense head now.

    The offline path (examples/serve_sketch_head.py) distills at a real
    budget and saves with ``SketchHead.save``; this fallback runs a short
    distillation so ``--sketch-head`` is self-contained at smoke scale.
    Returns a ready-to-serve :class:`repro.api.SketchHead`.  ``backend=None``
    keeps a loaded head on the decode backend it was saved with (the
    kind/backend round-trip); an explicit value overrides it.  ``quant``
    quantizes the count array post-load/post-freeze (``int8``/``int4``,
    DESIGN.md §12); it is a no-op when a loaded archive already carries the
    requested mode, and an error if it carries a different one.
    """
    from repro.core.distill import DistillConfig
    from repro.core.sketch_lm_head import distill_head, freeze_head

    if head_path:
        if not Path(head_path).exists():
            raise FileNotFoundError(
                f"--head-path {head_path} does not exist; run "
                f"examples/serve_sketch_head.py to distill and save a head, "
                f"or drop --head-path to distill one in-process")
        head = SketchHead.load(head_path)
        if backend is not None:
            head = head.with_backend(backend)
        l, r, v = head.params["array"].shape
        d = head.params["proj"].shape[0]
        if v != cfg.vocab_size or d != cfg.d_model:
            raise ValueError(
                f"sketch head {head_path} was frozen for (d_model={d}, "
                f"vocab={v}) but --arch {cfg.name} has "
                f"(d_model={cfg.d_model}, vocab={cfg.vocab_size})")
        if quant is not None and head.quant != quant:
            head = head.quantized(quant)   # raises on a conflicting mode
        print(f"loaded sketch head from {head_path} "
              f"(L={head.cfg.n_rows}, R={head.cfg.n_buckets}, "
              f"backend={head.backend}, quant={head.quant})")
        return head

    head_cfg = cfg.sketch_head or SketchHeadConfig(
        n_rows=128, n_buckets=16, k=1, proj_dim=32, bandwidth=2.0)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    hiddens = jax.random.normal(jax.random.PRNGKey(11),
                                (1024, cfg.d_model))
    print(f"distilling sketch head (L={head_cfg.n_rows}, "
          f"R={head_cfg.n_buckets}, {distill_steps} steps) …")
    kparams, metrics = distill_head(
        jax.random.PRNGKey(12), table, hiddens, head_cfg, n_points=256,
        distill_cfg=DistillConfig(n_steps=distill_steps, lr=5e-3))
    print(f"  distill MSE: {metrics['final_mse']:.5f}")
    return SketchHead(cfg=head_cfg, backend=backend or "fused", quant=quant,
                      params=freeze_head(jax.random.PRNGKey(13), kparams,
                                         head_cfg, quant=quant))


def build_tenant_heads(params, cfg, n_tenants: int,
                       backend: str | None = None, quant: str | None = None,
                       distill_steps: int = 300):
    """One shared quick distillation, ``n_tenants`` per-tenant freezes.

    Every tenant shares the distilled anchor set (points/alphas/transform)
    but freezes its own hash bank from a distinct key, so tenants emit
    genuinely different token streams at identical quality — the shape of
    a fleet serving one base model with per-customer heads (DESIGN.md §14).

    Returns ``(shared SketchHead spec, {tenant name: frozen params})``.
    """
    from repro.core.distill import DistillConfig
    from repro.core.sketch_lm_head import distill_head, freeze_head

    head_cfg = cfg.sketch_head or SketchHeadConfig(
        n_rows=128, n_buckets=16, k=1, proj_dim=32, bandwidth=2.0)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    hiddens = jax.random.normal(jax.random.PRNGKey(11), (1024, cfg.d_model))
    print(f"distilling shared tenant head (L={head_cfg.n_rows}, "
          f"R={head_cfg.n_buckets}, {distill_steps} steps) …")
    kparams, metrics = distill_head(
        jax.random.PRNGKey(12), table, hiddens, head_cfg, n_points=256,
        distill_cfg=DistillConfig(n_steps=distill_steps, lr=5e-3))
    print(f"  distill MSE: {metrics['final_mse']:.5f}")
    spec = SketchHead(cfg=head_cfg, backend=backend or "fused", quant=quant)
    heads = {f"tenant-{t}": freeze_head(jax.random.PRNGKey(100 + t),
                                        kparams, head_cfg, quant=quant)
             for t in range(n_tenants)}
    return spec, heads


def run_engine(lm, args, sampler: Sampler, head_cache=None) -> None:
    """Serve a synthetic request stream through the continuous-batching
    engine: staggered arrivals, skewed generation lengths, recycled slots.
    With ``--paged``, repeated prompts in the stream hit the prefix cache
    and skip their prefill entirely.  With ``--tenants N`` (``head_cache``
    set), requests round-robin over N per-tenant heads paged through the
    LRU HeadCache."""
    n_requests = args.requests or 2 * args.batch
    max_seq = args.prompt_len + args.gen
    engine = lm.engine(n_slots=args.batch, max_seq=max_seq, sampler=sampler,
                       decode_chunk=args.decode_chunk,
                       spec_decode=args.spec_decode, paged=args.paged,
                       page_size=args.page_size, head_cache=head_cache)
    rng = np.random.default_rng(args.seed)
    # A quarter of the prompt stream repeats a shared prompt so --paged has
    # prefix-cache traffic to show; the rest are unique.
    shared = rng.integers(0, lm.cfg.vocab_size, args.prompt_len,
                          dtype=np.int32)
    for i in range(n_requests):
        if i % 4 == 3:
            prompt = shared
        else:
            prompt = rng.integers(0, lm.cfg.vocab_size, args.prompt_len,
                                  dtype=np.int32)
        # Skewed length mix: even requests are short, odd run the full --gen.
        gen = args.gen if i % 2 else max(1, args.gen // 4)
        tenant = (f"tenant-{i % args.tenants}" if head_cache is not None
                  else None)
        engine.submit(prompt, gen, arrival=i * args.arrival_every,
                      tenant=tenant)

    t0 = time.time()
    finished = engine.run()
    dur = time.time() - t0
    n_generated = sum(len(v) for v in finished.values())
    print(f"arch={lm.cfg.name} head={lm.head.describe()} engine served "
          f"{len(finished)} requests over {args.batch} slots: "
          f"{n_generated} tokens in {dur:.1f}s "
          f"({n_generated / dur:.1f} tok/s incl. compile), "
          f"{engine.stats['decode_steps']} decode steps in "
          f"{engine.stats['megasteps']} dispatches (chunk "
          f"{engine.decode_chunk}), "
          f"slot utilization {engine.slot_utilization:.2f}")
    if engine.spec_decode:
        drafted = engine.stats["draft_tokens"]
        accepted = engine.stats["accepted_draft_tokens"]
        print(f"speculative: K={engine.spec_decode}, "
              f"{engine.stats['verify_calls']} verify calls, "
              f"acceptance {accepted}/{drafted} "
              f"({accepted / max(1, drafted):.2f})")
    if engine.paged:
        s = engine.stats
        print(f"paged: page_size={engine.page_size}, prefix hits "
              f"{s['prefix_hits']}/{s['prefix_queries']} "
              f"(rate {s['prefix_hits'] / max(1, s['prefix_queries']):.2f}), "
              f"{s['prefill_batches']} prefill batches, "
              f"{s['cow_copies']} COW copies, "
              f"pages in use peak {s['pages_in_use_peak']}")
    if head_cache is not None:
        hs = head_cache.stats
        print(f"tenants: {args.tenants} over HeadCache capacity "
              f"{head_cache.capacity}, hits {hs['hits']}/"
              f"{hs['hits'] + hs['misses']}, {hs['loads']} loads, "
              f"{hs['evictions']} evictions")
    first = finished[min(finished)]
    print("sample token ids:", np.asarray(first[:24]))
    if args.stats_json:
        # One parseable line: the engine stats dict plus run metadata, for
        # scripts/CI that scrape serving numbers without parsing prose.
        import json
        record = {"arch": lm.cfg.name, "head": lm.head.describe(),
                  "n_slots": args.batch, "requests": len(finished),
                  "tokens": n_generated, "seconds": round(dur, 3),
                  "paged": engine.paged,
                  "page_size": engine.page_size if engine.paged else None}
        record.update({k: int(v) for k, v in engine.stats.items()})
        if head_cache is not None:
            record["tenants"] = {
                "n_tenants": args.tenants,
                "capacity": head_cache.capacity,
                **{k: int(v) for k, v in head_cache.stats.items()}}
        print("STATS_JSON " + json.dumps(record, sort_keys=True))


def main() -> None:
    from repro.api.lm import LM

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / engine slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16,
                    help="generation length (engine: per-request max; the "
                         "synthetic mix skews between gen//4 and gen)")
    ap.add_argument("--sketch-head", action="store_true",
                    help="decode with the Representer-Sketch head instead "
                         "of the dense logit matmul")
    ap.add_argument("--head-path", default=None,
                    help="frozen head .npz from examples/serve_sketch_head.py")
    ap.add_argument("--backend", default=None,
                    choices=["fused", "two_kernel", "ref"],
                    help="sketch-head decode backend (DESIGN.md §8); "
                         "default: the backend a --head-path head was saved "
                         "with, else fused")
    ap.add_argument("--quant", default=None, choices=["int8", "int4"],
                    help="serve the sketch head from quantized count-array "
                         "storage (per-row symmetric scales, in-register "
                         "dequant — DESIGN.md §12)")
    ap.add_argument("--no-fused", action="store_true",
                    help="deprecated: alias for --backend two_kernel")
    ap.add_argument("--engine", action="store_true",
                    help="serve a request stream through the "
                         "continuous-batching engine instead of one static "
                         "batch")
    ap.add_argument("--requests", type=int, default=0,
                    help="engine mode: number of requests (default 2×batch)")
    ap.add_argument("--arrival-every", type=int, default=1,
                    help="engine mode: ticks between request arrivals")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="decode K tokens per on-device megastep "
                         "(launch/decode_loop.py, DESIGN.md §10); 1 = the "
                         "per-token host loop (bitwise-parity default)")
    ap.add_argument("--spec-decode", type=int, default=0,
                    help="speculative self-decode: the serving head drafts "
                         "K tokens per dispatch, one batched dense pass "
                         "verifies (DESIGN.md §11; output is bitwise the "
                         "dense stream; mutually exclusive with "
                         "--decode-chunk > 1)")
    ap.add_argument("--paged", action="store_true",
                    help="engine mode: paged decode-cache pool + exact-"
                         "prompt prefix cache (DESIGN.md §13) — bitwise the "
                         "contiguous stream, repeated prompts prefill once; "
                         "mutually exclusive with --decode-chunk > 1 and "
                         "--spec-decode")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per cache page with --paged (smaller pages "
                         "waste less tail memory but deepen the page table)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="engine mode with --sketch-head: serve N per-tenant "
                         "heads (one shared distillation, per-tenant hash "
                         "banks) paged through an LRU HeadCache; requests "
                         "round-robin over tenants (DESIGN.md §14; mutually "
                         "exclusive with --spec-decode and --head-path)")
    ap.add_argument("--stats-json", action="store_true",
                    help="engine mode: print the engine stats dict as one "
                         "parseable 'STATS_JSON {…}' line after the run")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling / request-stream seed")
    ap.add_argument("--mesh", default=None,
                    help="serve SPMD over a '<data>x<model>' device mesh "
                         "(e.g. '4x2'); on CPU, force devices first with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    args = ap.parse_args()
    if args.no_fused and args.backend is not None:
        ap.error("--no-fused is a deprecated alias for --backend two_kernel; "
                 "pass only --backend")
    if (args.paged or args.stats_json) and not args.engine:
        ap.error("--paged/--stats-json apply to engine mode; add --engine")
    if args.tenants:
        if not (args.engine and args.sketch_head):
            ap.error("--tenants needs --engine and --sketch-head")
        if args.head_path:
            ap.error("--tenants distills one shared head in-process; "
                     "--head-path is not supported")
        if args.spec_decode:
            ap.error("--tenants and --spec-decode are mutually exclusive "
                     "(the draft/verify megastep cannot re-gather per-slot "
                     "tenant bindings mid-draft)")
    backend = "two_kernel" if args.no_fused else args.backend

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.quant and not args.sketch_head:
        ap.error("--quant only applies to the sketch head; add --sketch-head")
    head = DenseHead()
    head_cache = None
    if args.tenants:
        from repro.api.heads import HeadCache
        head, tenant_heads = build_tenant_heads(params, cfg, args.tenants,
                                                backend, quant=args.quant)
        # Capacity below the tenant count (when traffic allows) so the smoke
        # run exercises paging in/out, not just residency.
        head_cache = HeadCache(tenant_heads.__getitem__,
                               capacity=max(1, min(args.tenants, args.batch)))
    elif args.sketch_head:
        head = build_or_load_head(params, cfg, args.head_path, backend,
                                  quant=args.quant)
    lm = LM(params, cfg, head)
    if args.mesh:
        lm = lm.with_mesh(args.mesh)
        print(f"serving over mesh {dict(zip(lm.mesh.axis_names, lm.mesh.devices.shape))}")
    sampler = Sampler(temperature=args.temperature, top_k=args.top_k,
                      top_p=args.top_p, seed=args.seed)

    if args.engine:
        run_engine(lm, args, sampler, head_cache=head_cache)
        return

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    enc = None
    if cfg.n_encoder_tokens:
        enc = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_encoder_tokens, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    out = lm.generate(prompts, args.gen, sampler=sampler,
                      encoder_states=enc, decode_chunk=args.decode_chunk,
                      spec_decode=args.spec_decode,
                      return_stats=bool(args.spec_decode))
    stats = None
    if args.spec_decode:
        out, stats = out
    dur = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"arch={cfg.name} head={lm.head.describe()} served {args.batch} "
          f"seqs, {total_tokens} tokens in {dur:.1f}s "
          f"({total_tokens / dur:.1f} tok/s incl. compile)")
    if stats is not None:
        print(f"speculative: K={args.spec_decode}, "
              f"{stats['verify_calls']} verify calls, acceptance "
              f"{stats['accepted_draft_tokens']}/{stats['draft_tokens']} "
              f"({stats['accepted_draft_tokens'] / max(1, stats['draft_tokens']):.2f})")
    print("sample token ids:", np.asarray(out[0, :24]))


if __name__ == "__main__":
    main()
