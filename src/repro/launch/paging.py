"""Paged decode-cache pool + prefix cache (host-side bookkeeping).

The contiguous engine gives every slot a fixed ``(max_seq, …)`` cache row,
so short requests strand memory and identical prompts re-prefill per
request.  This module owns the *host* half of the paged alternative
(DESIGN.md §13):

* :class:`PagePool` — a free-list allocator over fixed-size pages of the
  sequence axis, with per-page refcounts and the per-slot page table.  One
  page id addresses the same physical page index in **every** paged cache
  leaf (all layers, K and V, latent and rope), so the allocator is
  family-agnostic.  Page 0 is a permanently reserved all-zero page: a table
  entry of 0 means "unmapped", and gathers through it read zeros — bitwise
  identical to a fresh contiguous cache row, which is what makes the paged
  decode path's gathered view byte-equal to the contiguous pool.
* :class:`PrefixCache` — an exact-prompt map from prompt bytes to the pages
  that hold its prefilled KV state (plus the constant-size recurrent state
  and the prompt's last-position logits).  A hit maps the shared pages into
  the new slot copy-free; the refcounts make the sharing copy-on-write —
  the first decode write that lands on a page with other referents triggers
  a page copy (``ServeEngine._ensure_write_pages``).  Entries are LRU and
  evicted when the pool runs dry.

Both classes are pure numpy/stdlib — no JAX in the loop — so the refcount
and allocator invariants are property-testable without device state
(tests/test_paging_properties.py), mirroring how ``SlotScheduler`` keeps
scheduling testable apart from the model compute.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: The reserved all-zero page; table entries of 0 mean "unmapped".
ZERO_PAGE = 0


class PagePool:
    """Free-list page allocator + per-slot page table + per-page refcounts.

    Invariants (property-tested):

    * ``refcount[p]`` equals the number of live references to page ``p``:
      page-table entries plus external (prefix-cache entry) references.
    * A page is on the free list iff its refcount is 0; it is handed out
      again only after every referent dropped it (no use-after-free).
    * ``refcount[ZERO_PAGE]`` is pinned ≥ 1 forever — the zero page is
      never allocated, never freed, and never written by the host.
    * Allocation order is deterministic (LIFO free list), so runs replay
      bitwise.
    """

    def __init__(self, num_pages: int, n_slots: int, pages_per_slot: int):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is reserved), "
                             f"got {num_pages}")
        self.num_pages = num_pages
        self.n_slots = n_slots
        self.pages_per_slot = pages_per_slot
        self.refcount = np.zeros(num_pages, np.int64)
        self.refcount[ZERO_PAGE] = 1          # pinned: never allocatable
        # LIFO free list, lowest ids handed out first (deterministic).
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.table = np.zeros((n_slots, pages_per_slot), np.int32)
        self.page_allocs = 0
        self.peak_in_use = 0

    # -- allocator ---------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages currently referenced (excluding the reserved zero page)."""
        return self.num_pages - 1 - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages off the free list (refcount 1 each), or None
        when the pool can't cover the request (caller evicts and retries)."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for pid in ids:
            assert self.refcount[pid] == 0, f"freed page {pid} had refs"
            self.refcount[pid] = 1
        self.page_allocs += n
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return ids

    def incref(self, pid: int) -> None:
        assert pid != ZERO_PAGE and self.refcount[pid] > 0, \
            f"incref of dead/zero page {pid}"
        self.refcount[pid] += 1

    def decref(self, pid: int) -> None:
        """Drop one reference; a page hitting refcount 0 returns to the
        free list (a double free asserts instead of corrupting it)."""
        assert pid != ZERO_PAGE, "decref of the reserved zero page"
        assert self.refcount[pid] > 0, f"double free of page {pid}"
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self._free.append(pid)

    # -- page table --------------------------------------------------------

    def map_slot(self, slot: int, ids: Sequence[int], *,
                 owned: bool) -> None:
        """Map ``ids`` into table entries ``[0, len(ids))`` of ``slot``.

        ``owned=True`` transfers freshly allocated pages (refcount already
        1); ``owned=False`` shares existing pages (prefix hit) and increfs
        each.  The slot's row must be clear (engine retires before reuse).
        """
        assert not self.table[slot].any(), f"slot {slot} table not clear"
        for j, pid in enumerate(ids):
            if not owned:
                self.incref(pid)
            self.table[slot, j] = pid

    def map_index(self, slot: int, j: int, pid: int) -> None:
        """Map one freshly allocated page at table index ``j``."""
        assert self.table[slot, j] == ZERO_PAGE
        self.table[slot, j] = pid

    def remap(self, slot: int, j: int, pid: int) -> int:
        """Replace the mapping at index ``j`` (COW: new page already owned);
        drops the old page's reference and returns its id."""
        old = int(self.table[slot, j])
        assert old != ZERO_PAGE
        self.table[slot, j] = pid
        self.decref(old)
        return old

    def clear_slot(self, slot: int) -> None:
        """Unmap every page of ``slot`` (decref each; refcount-0 pages
        return to the free list — entry-shared pages survive)."""
        for j in range(self.pages_per_slot):
            pid = int(self.table[slot, j])
            if pid != ZERO_PAGE:
                self.decref(pid)
                self.table[slot, j] = ZERO_PAGE

    def slot_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self.table[slot] if p != ZERO_PAGE]

    def check_invariants(self, external_refs: Dict[int, int]) -> None:
        """Assert refcounts == table refs + ``external_refs`` and the free
        list holds exactly the refcount-0 pages (test helper)."""
        counts = np.zeros(self.num_pages, np.int64)
        counts[ZERO_PAGE] = 1
        for pid in self.table.ravel():
            if pid != ZERO_PAGE:
                counts[pid] += 1
        for pid, n in external_refs.items():
            counts[pid] += n
        assert (counts == self.refcount).all(), \
            f"refcount drift: {np.nonzero(counts != self.refcount)[0]}"
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages on free list"
        for pid in range(1, self.num_pages):
            assert (pid in free) == (self.refcount[pid] == 0)


@dataclasses.dataclass
class PrefixEntry:
    """One cached prompt: the pages holding its prefilled KV content, the
    constant-size recurrent state row (mamba/RWKV — no positional axis, so
    it rides the prefix cache, not the page pool), and the prompt's
    last-position logits (so a full hit skips the prefill entirely and
    samples the first token from the stored row, bitwise)."""
    page_ids: Tuple[int, ...]
    state: Any                   # pytree of (1, …) numpy rows (or None)
    logits: np.ndarray           # (V,) f32
    plen: int


class PrefixCache:
    """Exact-prompt prefix cache at page granularity, LRU-evicted.

    Keys are the prompt token bytes; a hit returns the entry whose pages are
    then mapped (shared, refcounted) into the admitted slot.  Registration
    increfs every page the entry references; eviction drops them — the
    clean invariant "refcount == number of live references" is what the
    property suite pins.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self.hits = 0
        self.queries = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self._entries

    def get(self, key: bytes) -> Optional[PrefixEntry]:
        """Look up a prompt; a hit refreshes its LRU position."""
        self.queries += 1
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(key)
        return entry

    def peek(self, key: bytes) -> Optional[PrefixEntry]:
        """Stats-free lookup (no query/hit counting, no LRU refresh) — for
        same-batch duplicates that were only just registered."""
        return self._entries.get(key)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.queries if self.queries else 0.0

    def register(self, key: bytes, page_ids: Sequence[int], state,
                 logits: np.ndarray, plen: int) -> PrefixEntry:
        """Record a freshly prefilled prompt; increfs every page."""
        assert key not in self._entries, "prompt already registered"
        for pid in page_ids:
            self.pool.incref(pid)
        entry = PrefixEntry(tuple(int(p) for p in page_ids), state,
                            np.asarray(logits), plen)
        self._entries[key] = entry
        return entry

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry (decref its pages); False when
        there is nothing left to evict."""
        if not self._entries:
            return False
        _, entry = self._entries.popitem(last=False)
        for pid in entry.page_ids:
            self.pool.decref(pid)
        return True

    def external_refs(self) -> Dict[int, int]:
        """page id → number of entry references (invariant-check helper)."""
        refs: Dict[int, int] = {}
        for entry in self._entries.values():
            for pid in entry.page_ids:
                refs[pid] = refs.get(pid, 0) + 1
        return refs
