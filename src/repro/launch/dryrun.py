import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices let ``make_production_mesh`` build the real 16×16 and 2×16×16
meshes; every step function is ``jax.jit(...).lower(...).compile()``'d
against abstract inputs (no allocation), and the compiled artifact yields

  * ``memory_analysis()``  — per-device bytes (does it fit HBM?)
  * ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes       — parsed from the optimized HLO text

Results are dumped as JSON per cell into ``results/dryrun/`` for
benchmarks/roofline.py to consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-12b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both    # everything
"""

import argparse
import functools
import json
import re
import sys
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_opt_state, abstract_params,
                                input_specs, opt_config_for, prefill_step,
                                serve_step, train_step)
from repro.optim.adamw import OptimizerConfig
from repro.sharding.ctx import activation_sharding
from repro.sharding.rules import (batch_spec, cache_shardings,
                                  params_shardings, zero1_shardings)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

def build_cell(arch: str, shape: str, mesh, *, smoke: bool = False):
    """Return (jitted_fn, example_args (abstract), donate info) for a cell."""
    spec = input_specs(arch, shape, smoke=smoke)
    cfg = spec["cfg"]
    kind = spec["kind"]
    params = abstract_params(cfg)
    pshard = params_shardings(params, mesh)
    bspec = batch_spec(spec["batch"], mesh)
    repl = NamedSharding(mesh, P())

    if kind == "train":
        opt_cfg = opt_config_for(cfg)
        opt = abstract_opt_state(cfg, lean=opt_cfg.lean)
        oshard = type(opt)(
            step=repl,
            mu=zero1_shardings(opt.mu, mesh),
            nu=zero1_shardings(opt.nu, mesh),
            master=(None if opt.master is None
                    else zero1_shardings(opt.master, mesh)),
        )
        bshard = {k: NamedSharding(mesh, P(bspec, *([None] * (v.ndim - 1))))
                  for k, v in spec["batch_inputs"].items()}
        metrics_shard = {k: repl for k in
                         ("loss", "ce", "aux", "grad_norm", "lr")}
        fn = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, metrics_shard),
            donate_argnums=(0, 1),
        )
        args = (params, opt, spec["batch_inputs"])
    elif kind == "prefill":
        tshard = NamedSharding(mesh, P(bspec, None))
        eshard = (NamedSharding(mesh, P(bspec, None, None))
                  if spec["encoder_states"] is not None else None)
        logit_shard = NamedSharding(mesh, P(bspec, "model"))
        if spec["encoder_states"] is not None:
            def fn(p, t, e, _cfg=cfg):
                return prefill_step(p, t, _cfg, encoder_states=e)
            jitted = jax.jit(fn, in_shardings=(pshard, tshard, eshard),
                             out_shardings=logit_shard)
            args = (params, spec["tokens"], spec["encoder_states"])
        else:
            fn = functools.partial(prefill_step, cfg=cfg)
            jitted = jax.jit(fn, in_shardings=(pshard, tshard),
                             out_shardings=logit_shard)
            args = (params, spec["tokens"])
    else:  # decode
        cache = spec["cache"]
        cshard = cache_shardings(cache, mesh, spec["batch"])
        tshard = NamedSharding(mesh, P(bspec, None))
        logit_shard = NamedSharding(mesh, P(bspec, "model"))
        if spec["encoder_states"] is not None:
            eshard = NamedSharding(mesh, P(bspec, None, None))
            def fn(p, c, t, pos, e, _cfg=cfg):
                return serve_step(p, c, t, pos, _cfg, encoder_states=e)
            jitted = jax.jit(fn,
                             in_shardings=(pshard, cshard, tshard, repl, eshard),
                             out_shardings=(logit_shard, cshard),
                             donate_argnums=(1,))
            args = (params, cache, spec["tokens"], spec["pos"],
                    spec["encoder_states"])
        else:
            fn = functools.partial(serve_step, cfg=cfg)
            jitted = jax.jit(fn,
                             in_shardings=(pshard, cshard, tshard, repl),
                             out_shardings=(logit_shard, cshard),
                             donate_argnums=(1,))
            args = (params, cache, spec["tokens"], spec["pos"])
    return jitted, args, cfg


def run_cell(arch: str, shape: str, mesh_kind: str, *, smoke: bool = False,
             save: bool = True, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    with mesh, activation_sharding(mesh):
        jitted, args, cfg = build_cell(arch, shape, mesh, smoke=smoke)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax ≥ 0.4.30 returns one properties dict; older versions wrapped it
        # in a per-device list.
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else None
        hlo = compiled.as_text()

    hl = analyze(hlo)

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # per-device numbers from the trip-weighted HLO analyzer
        "flops": hl["flops"],
        "elementwise_flops": hl["elementwise_flops"],
        "bytes_accessed": hl["bytes_accessed"],
        "bytes_bf16adj": hl["bytes_bf16adj"],
        "collective_bytes": hl["collective_bytes"],
        # raw cost_analysis for reference (undercounts scan bodies)
        "xla_flops": float(cost.get("flops", -1.0)) if cost else -1.0,
        "xla_bytes": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "n_periods": cfg.n_periods,
    }
    if verbose:
        print(f"[{arch} × {shape} × {mesh_kind}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s  "
              f"flops={result['flops']:.3e} "
              f"coll={hl['collective_bytes']['total']:.3e}B "
              f"temp={result['memory_analysis']['temp_size_bytes']}")
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out = RESULTS_DIR / f"{arch}__{shape}__{mesh_kind}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every runnable (arch × shape) cell")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (CI sanity)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = (list(cells()) if args.all
            else [(args.arch, args.shape)])
    failures = []
    for arch, shape in todo:
        for mk in meshes:
            try:
                run_cell(arch, shape, mk, smoke=args.smoke)
            except Exception as e:  # noqa: BLE001 — report-and-continue CLI
                failures.append((arch, shape, mk, repr(e)[:200]))
                print(f"FAIL [{arch} × {shape} × {mk}]: {e!r}",
                      file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
