"""End-to-end training launcher.

Runs real optimization on whatever devices exist (1-CPU smoke through the
production mesh), with the full substrate: sharded params/optimizer, async
checkpointing + restart, straggler tracking, optional int8 error-feedback
gradient compression on the DP all-reduce (``--grad-compress``; applied via
shard_map around the gradient step when the data axis is real).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-12b --smoke \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, PrefetchingLoader
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import train_step
from repro.models.model import init_model
from repro.optim.adamw import OptimizerConfig, init_adamw
from repro.runtime.failure import StragglerTracker
from repro.sharding.ctx import activation_sharding
from repro.sharding.rules import batch_spec, params_shardings, zero1_shardings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
    mesh = make_host_mesh(model=args.model_parallel)

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        n_encoder_tokens=cfg.n_encoder_tokens, d_model=cfg.d_model)
    loader = PrefetchingLoader(data_cfg)

    with mesh, activation_sharding(mesh):
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt_state = init_adamw(params)
        pshard = params_shardings(params, mesh)
        oshard = type(opt_state)(
            step=NamedSharding(mesh, P()),
            mu=zero1_shardings(opt_state.mu, mesh),
            nu=zero1_shardings(opt_state.nu, mesh),
            master=zero1_shardings(opt_state.master, mesh))
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)

        bspec = batch_spec(args.batch, mesh)
        step_fn = jax.jit(
            functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg),
            in_shardings=(pshard, oshard, None),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1))

        ckpt = (CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            (params, opt_state), start = ckpt.restore((params, opt_state))
            start += 1
            print(f"restored step {start - 1}")

        tracker = StragglerTracker()
        t_all = time.time()
        for step in range(start, args.steps):
            _, batch = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = jax.tree.map(float, metrics)
            tracker.record(0, time.time() - t0)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"ce {metrics['ce']:.4f} gnorm {metrics['grad_norm']:.3f} "
                      f"lr {metrics['lr']:.2e} "
                      f"({time.time() - t0:.2f}s)")
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, jax.tree.map(np.asarray, (params, opt_state)))
        if ckpt:
            ckpt.wait()
        dur = time.time() - t_all
        print(f"done: {args.steps - start} steps in {dur:.1f}s "
              f"({(args.steps - start) / max(dur, 1e-9):.2f} steps/s), "
              f"final loss {metrics['loss']:.4f}")
    loader.close()


if __name__ == "__main__":
    main()
