"""Jittable train / prefill / serve steps + abstract input specs per cell.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step that the (arch × shape) cell lowers — weak-type-correct,
shardable, and allocation-free, so the dry-run can ``.lower().compile()``
the production mesh without any device memory.
"""

from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.heads import DenseHead, LogitHead, SketchHead
from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig, SketchHeadConfig
from repro.models.model import (decode_step, forward, init_decode_cache,
                                init_model, lm_loss)
from repro.optim.adamw import AdamWState, OptimizerConfig, adamw_update, init_adamw


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def opt_config_for(cfg: ModelConfig, **kw) -> OptimizerConfig:
    """Default optimizer config per arch: above ~100B params use lean state
    (671B-class can't hold f32 master+moments on 16 GB chips) and
    8-way gradient accumulation (bounds activation transients)."""
    from repro.models.config import param_count
    big = param_count(cfg) > 100e9
    # accum sweep on deepseek-v3 train (§Perf iter 7): temp 307→77 GB going
    # 1→8, but FSDP expert weights re-gather once per microbatch, so
    # collective bytes rise 2.06e12→5.55e12 and bytes-accessed 4.5→7.4e13.
    # accum=2 keeps most of the transient relief at ~1.3× collective cost.
    kw.setdefault("grad_accum", 2 if big else 1)
    return OptimizerConfig(lean=big, **kw)


def train_step(params, opt_state: AdamWState, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, opt_cfg: OptimizerConfig):
    """One optimizer step. batch: tokens, labels[, encoder_states].

    With ``opt_cfg.grad_accum > 1`` the batch is split into microbatches
    along the batch axis and gradients are accumulated in a ``lax.scan`` —
    activation transients shrink by the accumulation factor while the
    optimizer sees the same global batch.
    """
    accum = opt_cfg.grad_accum

    def loss_fn(p, mb):
        return lm_loss(p, mb["tokens"], mb["labels"], cfg,
                       encoder_states=mb.get("encoder_states"))

    if accum == 1:
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
    else:
        micro = jax.tree.map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)

        def acc_step(carry, mb):
            gacc, lacc, pacc = carry
            (l, pr), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b / accum, gacc, g)
            pacc = jax.tree.map(lambda a, b: a + b / accum, pacc, pr)
            return (gacc, lacc + l / accum, pacc), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params)
        zeros_p = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
        (grads, loss, parts), _ = jax.lax.scan(
            acc_step, (zeros_g, jnp.zeros(()), zeros_p), micro)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)

    new_params, new_opt, opt_metrics = adamw_update(grads, opt_state, opt_cfg,
                                                    params=params)
    metrics = {"loss": loss, **parts, **opt_metrics}
    return new_params, new_opt, metrics


def _constrain_cache(cache, mesh):
    """Pin a (traced) decode cache to its per-leaf mesh sharding.

    Applied inside the jitted serve fns so prefill / decode / slot ops
    *preserve* cache shardings step over step instead of letting the SPMD
    partitioner drift (or worse, gather a slot pool to one device).
    """
    from repro.sharding.rules import cache_shardings
    return jax.lax.with_sharding_constraint(
        cache, cache_shardings(cache, mesh))


def prefill_step(params, tokens, cfg: ModelConfig,
                 encoder_states=None, cache=None, mesh=None):
    """Context ingestion: forward pass returning last-position logits.

    Without a cache this is the abstract dry-run shape (logits only).  With
    ``cache`` it is the serving bulk prefill: the whole (B, P) prompt runs in
    one forward pass that fills the decode cache, and ``(last_logits,
    new_cache)`` is returned — replacing P per-token decode steps.  With
    ``mesh`` the filled cache is constrained to the serving cache shardings
    (batch over data axes, features over model).
    """
    if cache is None:
        logits, _, _ = forward(params, tokens, cfg,
                               encoder_states=encoder_states, remat=False)
        return logits[:, -1]
    logits, new_cache, _ = forward(
        params, tokens, cfg, encoder_states=encoder_states,
        cache=cache, cache_pos=jnp.zeros((), jnp.int32), remat=False)
    if mesh is not None:
        new_cache = _constrain_cache(new_cache, mesh)
    return logits[:, -1], new_cache


def _legacy_sketch_spec(sketch_cfg, fused, params=None) -> SketchHead:
    """The single legacy (sketch_cfg, fused) → SketchHead mapping.

    Every deprecation shim funnels through here so the mapping cannot drift
    between call sites.  Serving frozen arrays without their config was a
    crash before the redesign; keep it a hard error rather than silently
    hashing with default bandwidth/buckets (which emits wrong tokens).
    """
    if sketch_cfg is None:
        raise ValueError(
            "legacy sketch-head params were passed without sketch_cfg; the "
            "frozen arrays are unusable without their SketchHeadConfig — "
            "pass head=repro.api.SketchHead(cfg=..., params=...) instead")
    return SketchHead(cfg=sketch_cfg,
                      backend="fused" if fused in (None, True)
                      else "two_kernel",
                      params=params)


def resolve_legacy_serving_kwargs(head, sampler, sketch_params, sketch_cfg,
                                  fused, greedy, seed, caller: str):
    """Map the pre-redesign serving kwargs (sketch head params/cfg +
    ``fused``/``greedy``/``seed``) onto (LogitHead, Sampler) for one release
    of grace.  Shared by generate(), the engine, and make_engine."""
    from repro.api.sampler import Sampler

    if (sketch_params is None and sketch_cfg is None and fused is None
            and greedy is None and seed is None):
        return head, sampler
    warnings.warn(
        f"the legacy {caller} kwargs (sketch head params/cfg, fused=, "
        f"greedy=, seed=) are deprecated; pass "
        f"head=repro.api.SketchHead(...) and sampler=repro.api.Sampler(...) "
        f"instead", DeprecationWarning, stacklevel=3)
    if head is None and (sketch_params is not None or sketch_cfg is not None):
        head = _legacy_sketch_spec(sketch_cfg, fused, sketch_params)
    if sampler is None and (greedy is not None or seed is not None):
        sampler = (Sampler() if greedy in (None, True)
                   else Sampler(temperature=1.0, seed=seed or 0))
    return head, sampler


def _resolve_head_shim(head, head_params, sketch_head, sketch_cfg, fused):
    """Map the pre-redesign ``sketch_head=/sketch_cfg=/fused=`` kwargs onto
    a (LogitHead spec, runtime params) pair.  One release of grace."""
    if sketch_head is None and sketch_cfg is None and fused is None:
        return head or DenseHead(), head_params
    warnings.warn(
        "serve_step(sketch_head=, sketch_cfg=, fused=) is deprecated; pass "
        "head=repro.api.SketchHead(cfg=..., backend=...) and "
        "head_params=<frozen arrays> instead", DeprecationWarning,
        stacklevel=3)
    if head is None and (sketch_head is not None or sketch_cfg is not None):
        head = _legacy_sketch_spec(sketch_cfg, fused)
    if head_params is None:
        head_params = sketch_head
    return head or DenseHead(), head_params


def serve_step(params, cache, tokens, pos, cfg: ModelConfig,
               encoder_states=None, head: Optional[LogitHead] = None,
               head_params=None, active=None, mesh=None,
               return_hidden: bool = False, sketch_head=None,
               sketch_cfg: Optional[SketchHeadConfig] = None, fused=None):
    """One decode step (one new token per sequence against the cache).

    ``head`` is a :class:`repro.api.heads.LogitHead` *spec* (hashable —
    close over it via functools.partial before jit).  A ``DenseHead`` (the
    default) takes the backbone's own unembed logits.  A head with
    ``needs_hidden`` (e.g. ``SketchHead``) skips the dense h·Wᵀ matmul
    entirely: the backbone returns the final hidden and the head produces
    the (B, V) logits on its configured backend (``fused`` — one Pallas
    call, ``two_kernel``, or ``ref``); its frozen arrays arrive as the
    runtime argument ``head_params``.  The old ``sketch_head=/sketch_cfg=/
    fused=`` kwargs still work behind a DeprecationWarning.

    Continuous batching: ``pos`` may be per-slot (B,) counters, and
    ``active`` a (B,) bool mask — cache rows of inactive (free/padded) slots
    are kept bitwise unchanged, so a parked slot neither attends nor decays
    state while it waits for a new request.

    Sharded serving: ``mesh`` (static; threaded by ``jitted_serve_fns``)
    routes stateful heads through their shard_map path and re-constrains the
    updated cache to the serving cache shardings every step.

    ``return_hidden=True`` additionally returns the (B, d_model) final
    hidden as a third element — the input a speculative verify pass consumes
    (DESIGN.md §11).  A ``DenseHead`` under this flag produces its logits
    via ``dense_verify_logits`` on that hidden, bitwise-identical to the
    in-backbone unembed it normally takes.
    """
    from repro.models.model import mask_cache_update

    head, head_params = _resolve_head_shim(head, head_params, sketch_head,
                                           sketch_cfg, fused)
    hidden = None
    if not head.needs_hidden and not return_hidden:
        logits, new_cache = decode_step(params, cache, tokens, pos, cfg,
                                        encoder_states=encoder_states)
    else:
        from repro.models.layers import softcap

        hidden, new_cache = decode_step(params, cache, tokens, pos, cfg,
                                        encoder_states=encoder_states,
                                        return_hidden=True)
        if head.needs_hidden:
            logits = head.apply(head_params, hidden, mesh=mesh)
            if cfg.final_logit_softcap:
                logits = softcap(logits, cfg.final_logit_softcap)
        else:
            from repro.models.model import dense_verify_logits
            logits = dense_verify_logits(params, hidden, cfg)
    if active is not None:
        new_cache = mask_cache_update(cfg, cache, new_cache, active)
    if mesh is not None:
        new_cache = _constrain_cache(new_cache, mesh)
    if return_hidden:
        return logits, new_cache, hidden
    return logits, new_cache


class PagedServeFns(tuple):
    """Jitted paged-pool ops for one (cfg, mesh, max_seq, page_size) spec
    (DESIGN.md §13).  ``gather(pages, pt)`` materializes per-slot views;
    ``commit(pages, view, pt, pos)`` scatters the decode-written position
    back; ``insert(pages, src, pt_rows)`` lands freshly prefilled rows;
    ``page_copy(pages, src_ids, dst_ids)`` forks COW pages.  Everything but
    ``gather`` **donates** the arena — rebind to the returned tree.
    """

    def __new__(cls, gather, commit, insert, page_copy, page_size, max_seq):
        self = super().__new__(cls, (gather, commit, insert, page_copy))
        self.gather, self.commit = gather, commit
        self.insert, self.page_copy = insert, page_copy
        self.page_size, self.max_seq = page_size, max_seq
        return self


class ServeFns(tuple):
    """The jitted serving callables for one (cfg, head, mesh, chunk) spec.

    Unpacks as the legacy 4-tuple ``(prefill, decode, insert, reset)``;
    the on-device K-step decode loop is the extra ``megastep`` attribute
    (``None`` at ``decode_chunk=1`` — the bitwise-parity host-loop default)
    and the speculative two-head megastep is ``spec_megastep`` (``None``
    unless requested via ``spec_decode=K``).  With ``paged=True`` the
    ``paged_ops`` attribute carries the :class:`PagedServeFns` arena ops —
    the core decode itself stays the *same* compiled executable, fed the
    gathered view (that identity is the bitwise-parity argument).
    ``decode`` / ``insert`` / ``reset`` / ``megastep`` / ``spec_megastep``
    **donate** their cache/pool argument: the passed-in cache is consumed
    and callers must rebind to the returned one (launch/decode_loop.py).
    """

    def __new__(cls, prefill, decode, insert, reset, megastep=None,
                spec_megastep=None, paged_ops=None):
        self = super().__new__(cls, (prefill, decode, insert, reset))
        self.prefill, self.decode = prefill, decode
        self.insert, self.reset = insert, reset
        self.megastep = megastep
        self.spec_megastep = spec_megastep
        self.paged_ops = paged_ops
        return self


def jitted_serve_fns(cfg: ModelConfig, head: Optional[LogitHead] = None,
                     fused=None, *, mesh=None, sampler=None,
                     decode_chunk: int = 1, spec_decode: int = 0,
                     eos_id: Optional[int] = None, paged: bool = False,
                     page_size: int = 16, max_seq: Optional[int] = None):
    """Jitted (prefill, decode, slot_insert, slot_reset[, megastep]) for one
    serving config.  Memoized on ``(cfg, head spec, mesh, sampler,
    decode_chunk, eos_id)`` — all hashable — so every ``generate()`` call
    and every engine instance for the same spec reuses one compile cache; a
    fresh ``jax.jit(partial(...))`` per call would recompile each time.  The
    head's frozen arrays are *not* part of the key: pass them per call as
    ``head_params``.

    ``decode`` and the slot ops **donate** their cache/pool argument —
    the update happens in place instead of copying the full cache per
    token — so a cache passed in is consumed; rebind to the returned one.
    With ``decode_chunk > 1`` (needs ``sampler``), the returned struct's
    ``megastep`` is the on-device K-step decode loop
    (``launch.decode_loop.jitted_megastep``) fusing that sampler and the
    ``eos_id`` retirement into one ``lax.scan`` dispatch.

    With ``spec_decode = K > 0`` (needs ``sampler``; mutually exclusive with
    ``decode_chunk > 1``), the returned struct's ``spec_megastep`` is the
    speculative two-head megastep
    (``launch.decode_loop.jitted_spec_megastep``): the ``head`` drafts K
    tokens through the backbone and one batched dense pass verifies the
    block, emitting a stream bitwise-identical to pure dense decode
    (DESIGN.md §11).

    With ``mesh``, every returned fn is mesh-aware: prefill/decode constrain
    their output cache to the serving cache shardings, stateful heads run
    their shard_map path, and the slot ops preserve the pool's shardings
    across insert/reset instead of letting rows gather to one device —
    donation aliases buffers shard-for-shard under the same constraints.

    With ``paged=True`` (needs ``max_seq``; host decode loop only, so
    mutually exclusive with ``decode_chunk > 1`` and ``spec_decode``), the
    returned struct's ``paged_ops`` carries the jitted page-arena ops
    (:class:`PagedServeFns`); the core four fns are unchanged — the paged
    engine feeds the *same* compiled decode the gathered view.

    Accepts the pre-redesign ``(cfg, sketch_cfg, fused)`` calling convention
    behind a DeprecationWarning.
    """
    if isinstance(head, SketchHeadConfig) or fused is not None:
        warnings.warn(
            "jitted_serve_fns(cfg, sketch_cfg, fused) is deprecated; pass a "
            "repro.api LogitHead spec instead", DeprecationWarning,
            stacklevel=2)
        sketch_cfg = head if isinstance(head, SketchHeadConfig) else None
        head = (_legacy_sketch_spec(sketch_cfg, fused)
                if sketch_cfg is not None else DenseHead())
    head = (head or DenseHead()).without_params()
    if decode_chunk < 1:
        raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
    if decode_chunk > 1 and sampler is None:
        raise ValueError("decode_chunk > 1 fuses sampling into the decode "
                         "scan; pass sampler=repro.api.Sampler(...)")
    if spec_decode < 0:
        raise ValueError(f"spec_decode must be >= 0, got {spec_decode}")
    if spec_decode and decode_chunk > 1:
        raise ValueError("spec_decode and decode_chunk > 1 are mutually "
                         "exclusive: the speculative megastep already "
                         "advances up to K tokens per dispatch")
    if spec_decode and sampler is None:
        raise ValueError("spec_decode fuses sampling into the draft/verify "
                         "scan; pass sampler=repro.api.Sampler(...)")
    if spec_decode and getattr(head, "per_tenant", False):
        raise ValueError("spec_decode and per-tenant heads are mutually "
                         "exclusive: the draft/verify megastep re-reads the "
                         "head inside its scan and cannot re-gather per-slot "
                         "tenant bindings mid-draft")
    if paged:
        if decode_chunk > 1:
            raise ValueError("paged serving gathers/commits pages around "
                             "each host decode step; decode_chunk > 1 (the "
                             "on-device megastep) is not supported yet")
        if spec_decode:
            raise ValueError("paged serving and spec_decode are mutually "
                             "exclusive: the draft/verify megastep manages "
                             "its own contiguous pool")
        if max_seq is None:
            raise ValueError("paged=True needs max_seq= to size the "
                             "per-slot page tables")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
    # The four core fns don't depend on (sampler, decode_chunk, eos_id), so
    # they memoize on (cfg, head, mesh) alone — a new sampler spec must not
    # recompile the model steps.  The megasteps have their own memo caches in
    # decode_loop.py keyed on the full spec.
    fns = _jitted_serve_fns(cfg, head, mesh)
    if paged:
        return ServeFns(*fns, None, None,
                        _paged_serve_fns(cfg, mesh, max_seq, page_size))
    if decode_chunk == 1 and not spec_decode:
        return fns   # the memoized instance itself (stable identity)
    if spec_decode:
        from repro.launch.decode_loop import jitted_spec_megastep
        return ServeFns(*fns, None,
                        jitted_spec_megastep(cfg, head, sampler, spec_decode,
                                             mesh=mesh, eos_id=eos_id,
                                             masked=True))
    from repro.launch.decode_loop import jitted_megastep
    return ServeFns(*fns, jitted_megastep(cfg, head, sampler, decode_chunk,
                                          mesh=mesh, eos_id=eos_id,
                                          masked=True))


@functools.lru_cache(maxsize=None)
def _jitted_serve_fns(cfg: ModelConfig, head: LogitHead, mesh=None):
    from repro.models.model import cache_slot_insert, cache_slot_reset

    prefill = jax.jit(functools.partial(prefill_step, cfg=cfg, mesh=mesh))
    decode = jax.jit(functools.partial(serve_step, cfg=cfg, head=head,
                                       mesh=mesh), donate_argnums=(1,))

    def slot_op(fn):
        def op(pool, *args):
            out = fn(cfg, pool, *args)
            return out if mesh is None else _constrain_cache(out, mesh)
        return jax.jit(op, donate_argnums=(0,))

    insert = slot_op(cache_slot_insert)
    reset = slot_op(cache_slot_reset)
    return ServeFns(prefill, decode, insert, reset)


@functools.lru_cache(maxsize=None)
def _paged_serve_fns(cfg: ModelConfig, mesh, max_seq: int, page_size: int):
    """Jitted page-arena ops, memoized per (cfg, mesh, max_seq, page_size).

    Head-independent: the arena never meets the logit head, so every head
    spec over the same backbone shares one compile cache.  ``gather`` is the
    only non-donating op (the arena must survive it — the view is a copy);
    ``commit`` / ``insert`` / ``page_copy`` donate the arena and the caller
    rebinds.  Under a mesh, views are constrained to the contiguous cache
    shardings (so the shared decode executable sees identical layouts) and
    arenas to ``page_pool_shardings``.
    """
    from repro.models.model import (paged_commit_cache, paged_copy_pages,
                                    paged_gather_cache, paged_insert_cache)

    def constrain_pages(pages):
        if mesh is None:
            return pages
        from repro.sharding.rules import page_pool_shardings
        return jax.lax.with_sharding_constraint(
            pages, page_pool_shardings(pages, mesh))

    def gather(pages, pt):
        view = paged_gather_cache(cfg, pages, pt, max_seq)
        return view if mesh is None else _constrain_cache(view, mesh)

    def commit(pages, view, pt, pos):
        return constrain_pages(
            paged_commit_cache(cfg, pages, view, pt, pos, max_seq))

    def insert(pages, src, pt_rows):
        return constrain_pages(paged_insert_cache(cfg, pages, src, pt_rows))

    def page_copy(pages, src_ids, dst_ids):
        return constrain_pages(paged_copy_pages(cfg, pages, src_ids, dst_ids))

    return PagedServeFns(
        jax.jit(gather),
        jax.jit(commit, donate_argnums=(0,)),
        jax.jit(insert, donate_argnums=(0,)),
        jax.jit(page_copy, donate_argnums=(0,)),
        page_size, max_seq)


@functools.lru_cache(maxsize=None)
def expand_rows_fn(cfg: ModelConfig):
    """Jitted ``model.cache_expand_rows`` for one config (admission dedupe:
    expand a deduped prefill's cache rows back to one per request)."""
    from repro.models.model import cache_expand_rows
    return jax.jit(functools.partial(cache_expand_rows, cfg))


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig, lean: bool = False):
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(init_adamw, lean=lean), params)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(init_decode_cache, cfg, batch, max_seq))


def input_specs(arch: str, shape: str, *, smoke: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch × shape) dry-run cell.

    Returns a dict with 'kind' ∈ {train, prefill, decode} and the abstract
    arrays each step consumes.
    """
    cfg = get_config(arch, smoke=smoke)
    seq, batch, kind = SHAPES[shape]
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    bf16 = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)

    out: Dict[str, Any] = {"kind": kind, "cfg": cfg, "seq": seq, "batch": batch}
    enc = (bf16(batch, cfg.n_encoder_tokens, cfg.d_model)
           if cfg.n_encoder_tokens else None)
    if kind == "train":
        out["batch_inputs"] = {"tokens": i32(batch, seq), "labels": i32(batch, seq)}
        if enc is not None:
            out["batch_inputs"]["encoder_states"] = enc
    elif kind == "prefill":
        out["tokens"] = i32(batch, seq)
        out["encoder_states"] = enc
    else:  # decode: one new token against a cache of length seq
        out["tokens"] = i32(batch, 1)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["cache"] = abstract_cache(cfg, batch, seq)
        out["encoder_states"] = enc
    return out
