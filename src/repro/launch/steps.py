"""Jittable train / prefill / serve steps + abstract input specs per cell.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step that the (arch × shape) cell lowers — weak-type-correct,
shardable, and allocation-free, so the dry-run can ``.lower().compile()``
the production mesh without any device memory.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig, SketchHeadConfig
from repro.models.model import (decode_step, forward, init_decode_cache,
                                init_model, lm_loss)
from repro.optim.adamw import AdamWState, OptimizerConfig, adamw_update, init_adamw


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def opt_config_for(cfg: ModelConfig, **kw) -> OptimizerConfig:
    """Default optimizer config per arch: above ~100B params use lean state
    (671B-class can't hold f32 master+moments on 16 GB chips) and
    8-way gradient accumulation (bounds activation transients)."""
    from repro.models.config import param_count
    big = param_count(cfg) > 100e9
    # accum sweep on deepseek-v3 train (§Perf iter 7): temp 307→77 GB going
    # 1→8, but FSDP expert weights re-gather once per microbatch, so
    # collective bytes rise 2.06e12→5.55e12 and bytes-accessed 4.5→7.4e13.
    # accum=2 keeps most of the transient relief at ~1.3× collective cost.
    kw.setdefault("grad_accum", 2 if big else 1)
    return OptimizerConfig(lean=big, **kw)


def train_step(params, opt_state: AdamWState, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, opt_cfg: OptimizerConfig):
    """One optimizer step. batch: tokens, labels[, encoder_states].

    With ``opt_cfg.grad_accum > 1`` the batch is split into microbatches
    along the batch axis and gradients are accumulated in a ``lax.scan`` —
    activation transients shrink by the accumulation factor while the
    optimizer sees the same global batch.
    """
    accum = opt_cfg.grad_accum

    def loss_fn(p, mb):
        return lm_loss(p, mb["tokens"], mb["labels"], cfg,
                       encoder_states=mb.get("encoder_states"))

    if accum == 1:
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
    else:
        micro = jax.tree.map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)

        def acc_step(carry, mb):
            gacc, lacc, pacc = carry
            (l, pr), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b / accum, gacc, g)
            pacc = jax.tree.map(lambda a, b: a + b / accum, pacc, pr)
            return (gacc, lacc + l / accum, pacc), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params)
        zeros_p = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
        (grads, loss, parts), _ = jax.lax.scan(
            acc_step, (zeros_g, jnp.zeros(()), zeros_p), micro)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)

    new_params, new_opt, opt_metrics = adamw_update(grads, opt_state, opt_cfg,
                                                    params=params)
    metrics = {"loss": loss, **parts, **opt_metrics}
    return new_params, new_opt, metrics


def prefill_step(params, tokens, cfg: ModelConfig,
                 encoder_states=None, cache=None):
    """Context ingestion: forward pass returning last-position logits.

    Without a cache this is the abstract dry-run shape (logits only).  With
    ``cache`` it is the serving bulk prefill: the whole (B, P) prompt runs in
    one forward pass that fills the decode cache, and ``(last_logits,
    new_cache)`` is returned — replacing P per-token decode steps.
    """
    if cache is None:
        logits, _, _ = forward(params, tokens, cfg,
                               encoder_states=encoder_states, remat=False)
        return logits[:, -1]
    logits, new_cache, _ = forward(
        params, tokens, cfg, encoder_states=encoder_states,
        cache=cache, cache_pos=jnp.zeros((), jnp.int32), remat=False)
    return logits[:, -1], new_cache


def serve_step(params, cache, tokens, pos, cfg: ModelConfig,
               encoder_states=None, sketch_head=None,
               sketch_cfg: Optional[SketchHeadConfig] = None,
               fused: bool = True, active=None):
    """One decode step (one new token per sequence against the cache).

    With ``sketch_head`` (frozen params from
    ``repro.core.sketch_lm_head.freeze_head``) the dense h·Wᵀ logit matmul is
    skipped entirely: the backbone returns the final hidden and the
    Representer-Sketch head produces the (B, V) logits — fused into a single
    Pallas call (repro.kernels.fused_decode) unless ``fused=False`` selects
    the two-kernel lsh_hash → sketch_head baseline.  ``sketch_cfg`` must be
    the head's static SketchHeadConfig (hashable; close over it via
    functools.partial before jit).

    Continuous batching: ``pos`` may be per-slot (B,) counters, and
    ``active`` a (B,) bool mask — cache rows of inactive (free/padded) slots
    are kept bitwise unchanged, so a parked slot neither attends nor decays
    state while it waits for a new request.
    """
    from repro.models.model import mask_cache_update

    if sketch_head is None:
        logits, new_cache = decode_step(params, cache, tokens, pos, cfg,
                                        encoder_states=encoder_states)
    else:
        from repro.core.sketch_lm_head import apply_head
        from repro.models.layers import softcap

        hidden, new_cache = decode_step(params, cache, tokens, pos, cfg,
                                        encoder_states=encoder_states,
                                        return_hidden=True)
        logits = apply_head(sketch_head, hidden, sketch_cfg, fused=fused)
        if cfg.final_logit_softcap:
            logits = softcap(logits, cfg.final_logit_softcap)
    if active is not None:
        new_cache = mask_cache_update(cfg, cache, new_cache, active)
    return logits, new_cache


@functools.lru_cache(maxsize=None)
def jitted_serve_fns(cfg: ModelConfig,
                     sketch_cfg: Optional[SketchHeadConfig] = None,
                     fused: bool = True):
    """Jitted (prefill, decode, slot_insert, slot_reset) for one serving
    config.  Memoized on the (hashable) configs so every ``generate()`` call
    and every engine instance for the same model reuses one compile cache —
    a fresh ``jax.jit(partial(...))`` per call would recompile each time.
    """
    from repro.models.model import cache_slot_insert, cache_slot_reset

    prefill = jax.jit(functools.partial(prefill_step, cfg=cfg))
    decode = jax.jit(functools.partial(serve_step, cfg=cfg,
                                       sketch_cfg=sketch_cfg, fused=fused))
    insert = jax.jit(functools.partial(cache_slot_insert, cfg))
    reset = jax.jit(functools.partial(cache_slot_reset, cfg))
    return prefill, decode, insert, reset


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig, lean: bool = False):
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(init_adamw, lean=lean), params)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        functools.partial(init_decode_cache, cfg, batch, max_seq))


def input_specs(arch: str, shape: str, *, smoke: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch × shape) dry-run cell.

    Returns a dict with 'kind' ∈ {train, prefill, decode} and the abstract
    arrays each step consumes.
    """
    cfg = get_config(arch, smoke=smoke)
    seq, batch, kind = SHAPES[shape]
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    bf16 = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)

    out: Dict[str, Any] = {"kind": kind, "cfg": cfg, "seq": seq, "batch": batch}
    enc = (bf16(batch, cfg.n_encoder_tokens, cfg.d_model)
           if cfg.n_encoder_tokens else None)
    if kind == "train":
        out["batch_inputs"] = {"tokens": i32(batch, seq), "labels": i32(batch, seq)}
        if enc is not None:
            out["batch_inputs"]["encoder_states"] = enc
    elif kind == "prefill":
        out["tokens"] = i32(batch, seq)
        out["encoder_states"] = enc
    else:  # decode: one new token against a cache of length seq
        out["tokens"] = i32(batch, 1)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["cache"] = abstract_cache(cfg, batch, seq)
        out["encoder_states"] = enc
    return out
