"""Continuous-batching serve engine: slotted decode cache + FIFO admission.

The static path (``launch.serve.generate``) runs one fixed batch from prefill
to the last token — a request that finishes early pads the batch until the
slowest one is done, and nothing can join mid-decode.  This engine owns a
pool of ``n_slots`` decode-cache rows and a FIFO request queue instead:

* **admit** — whenever a slot is free and a request has arrived, its prompt
  is bulk-prefilled into a *fresh* cache (the exact prefill path the static
  server uses) and the filled rows are copied into the pool via
  ``cache_slot_insert``; simultaneous arrivals with equal prompt lengths
  prefill as one batch.
* **decode** — one ``serve_step`` per engine tick advances every occupied
  slot, with per-slot position counters (each sequence is at its own depth)
  and an active-slot mask so free slots keep their cache bitwise unchanged.
  With ``decode_chunk=K`` the tick becomes an on-device *megastep*: K
  steps, sampling, and EOS retirement fused into one ``lax.scan`` dispatch
  (launch/decode_loop.py, DESIGN.md §10), clamped so no slot overshoots
  its budget.  Greedy streams are bitwise K-invariant; seeded streams are
  K-invariant unless a mid-chunk EOS delays a re-admission (a freed slot
  refills only at the chunk boundary), which shifts the shared key chain
  — reproducible per (seed, K), documented in docs/serving.md.
* **retire** — a sequence leaves individually on EOS or its own
  ``max_new_tokens``; the slot is ``cache_slot_reset`` to a fresh (bitwise
  zero) row and immediately reusable on the next tick.

The request queue is a heap ordered on (arrival, submission) —
O(log n) per request — and the jitted decode/slot ops donate the pool
(no per-token cache copy; the engine always rebinds ``self.pool`` to the
returned one).

The engine is head-agnostic through the ``repro.api`` objects: any
registered ``LogitHead`` (dense unembed, fused sketch head, the two-kernel
path, …) runs through the same ``serve_step``, and token selection is a
``Sampler`` (DESIGN.md §7/§8).  Scheduling bookkeeping lives in the
pure-Python ``SlotScheduler`` and the model compute behind the small
``EngineBackend`` seam, so scheduler invariants are property-testable
without JAX in the loop (tests/test_engine_properties.py).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.heads import DenseHead, LogitHead
from repro.api.sampler import Sampler
from repro.launch.steps import (jitted_serve_fns,
                                resolve_legacy_serving_kwargs)
from repro.models.config import ModelConfig, SketchHeadConfig
from repro.models.model import init_decode_cache


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens + generation budget.

    ``tenant`` binds the request to a per-tenant sketch head (DESIGN.md
    §14): on a ``head_cache`` engine every request must name its tenant,
    and its slot decodes through that tenant's head for its whole lifetime.
    """
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int
    arrival: int = 0            # engine tick at which the request is visible
    tenant: Optional[object] = None


class RequestQueue:
    """Arrival-ordered request queue, FIFO on ties: a binary heap keyed on
    ``(arrival, submission index)``.

    Replaces the sorted list the engine used to keep (``bisect.insort`` +
    ``list.pop(0)``): both ends of that were O(n) per request — O(n²) over a
    long arrival stream — where the heap is O(log n) push/pop.  Semantics
    are unchanged: ``pop`` returns the earliest arrival, and equal arrivals
    leave in submission order (the tie-break index), exactly the old
    insort-right behavior.
    """

    def __init__(self):
        self._heap: List[tuple] = []
        self._pushed = 0

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.arrival, self._pushed, req))
        self._pushed += 1

    def peek(self) -> Request:
        return self._heap[0][2]

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)

    def __iter__(self):
        """Pending requests in pop order (sorted snapshot — O(n log n);
        for diagnostics, not the hot path)."""
        return (entry[2] for entry in sorted(self._heap))

    def __getitem__(self, i: int) -> Request:
        # Legacy list-style indexing (``engine.queue[0]``); the head is the
        # O(1) case, anything else sorts a snapshot.  Slices would silently
        # return raw heap tuples — reject them.
        if not isinstance(i, int):
            raise TypeError(f"RequestQueue indices must be int, got {i!r}")
        if i == 0:
            return self.peek()
        return sorted(self._heap)[i][2]


class SlotScheduler:
    """Slot-pool bookkeeping: admission and retirement, no model compute.

    Invariants (property-tested): a slot is never double-assigned, every
    admitted request retires exactly once, and ``n_free + n_active ==
    n_slots`` at all times.  Free slots are handed out lowest-index first so
    runs are deterministic.
    """

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots))
        self.owner: Dict[int, int] = {}       # slot -> rid
        self.retired: Dict[int, int] = {}     # rid -> retire count

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return len(self.owner)

    def active_slots(self) -> List[int]:
        return sorted(self.owner)

    def admit(self, rid: int) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        if rid in self.owner.values() or rid in self.retired:
            raise RuntimeError(f"request {rid} already admitted")
        slot = min(self._free)
        self._free.remove(slot)
        self.owner[slot] = rid
        return slot

    def retire(self, slot: int) -> int:
        rid = self.owner.pop(slot)
        self.retired[rid] = self.retired.get(rid, 0) + 1
        self._free.append(slot)
        return rid


class EngineBackend:
    """Model compute behind the engine: prefill / insert / decode / reset.

    One instance per (model, head) pair; the jitted callables are memoized
    per (config, head spec) — ``jitted_serve_fns`` — so many engines over
    the same model share compiles.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 head: Optional[LogitHead] = None, mesh=None,
                 sketch_head=None,
                 sketch_cfg: Optional[SketchHeadConfig] = None, fused=None):
        if cfg.n_encoder_tokens:
            raise NotImplementedError(
                "engine serving of encoder-conditioned archs needs "
                "per-request encoder states; use launch.serve.generate")
        head, _ = resolve_legacy_serving_kwargs(
            head, None, sketch_head, sketch_cfg, fused, None, None,
            "EngineBackend")
        self.cfg = cfg
        self.head = head or DenseHead()
        self.mesh = mesh
        if mesh is not None:
            # Serving SPMD: params per sharding/rules.py, head count arrays
            # over model; no-op when the LM facade already placed them.
            from repro.launch.mesh import place_serving_state
            params, self.head = place_serving_state(params, self.head, mesh)
        self.params = params
        self.vocab_size = cfg.vocab_size
        (self._prefill, self._decode, self._insert,
         self._reset) = jitted_serve_fns(cfg, self.head.without_params(),
                                         mesh=mesh)

    def _place_cache(self, cache):
        if self.mesh is None:
            return cache
        from repro.sharding.rules import cache_shardings
        return jax.device_put(cache, cache_shardings(cache, self.mesh))

    def init_pool(self, n_slots: int, max_seq: int):
        return self._place_cache(init_decode_cache(self.cfg, n_slots, max_seq))

    def prefill(self, prompts: jnp.ndarray, max_seq: int):
        """Bulk-prefill (G, P) prompts into a fresh cache → (logits, cache)."""
        fresh = self._place_cache(
            init_decode_cache(self.cfg, prompts.shape[0], max_seq))
        logits, filled = self._prefill(self.params, prompts, cache=fresh)
        return np.asarray(logits), filled

    def insert(self, pool, filled, slots: np.ndarray):
        return self._insert(pool, filled, jnp.asarray(slots, jnp.int32))

    def reset(self, pool, slots: np.ndarray):
        return self._reset(pool, jnp.asarray(slots, jnp.int32))

    def decode(self, pool, tokens: np.ndarray, pos: np.ndarray,
               active: np.ndarray, head_params=None):
        """One decode step; ``head_params`` overrides the backend's bound
        head arrays (the per-tenant engine passes the HeadCache bank +
        slot binding here each tick)."""
        if head_params is None:
            head_params = self.head.params
        logits, pool = self._decode(
            self.params, pool, jnp.asarray(tokens[:, None], jnp.int32),
            jnp.asarray(pos, jnp.int32), head_params=head_params,
            active=jnp.asarray(active))
        return np.asarray(logits), pool

    # -- paged pool (DESIGN.md §13) ----------------------------------------

    def _paged_fns(self, max_seq: int, page_size: int):
        return jitted_serve_fns(self.cfg, self.head.without_params(),
                                mesh=self.mesh, paged=True,
                                page_size=page_size, max_seq=max_seq).paged_ops

    def paged_geometries(self, max_seq: int):
        """Distinct (size, ring) sequence-axis geometries across this
        model's paged layer families — what the engine's write-page logic
        iterates to find the page each family writes at a position."""
        from repro.models.blocks import paged_geometry
        kinds = set(self.cfg.pattern)
        geoms = {paged_geometry(self.cfg, k, max_seq) for k in kinds}
        return sorted(g for g in geoms if g is not None)

    def init_paged(self, n_slots: int, max_seq: int, page_size: int,
                   num_pages: int):
        """Device state for the paged engine: the (pages, state) tree pair."""
        from repro.models.model import init_paged_cache, init_paged_state
        pages = init_paged_cache(self.cfg, num_pages, page_size)
        state = init_paged_state(self.cfg, n_slots)
        if self.mesh is not None:
            from repro.sharding.rules import page_pool_shardings
            pages = jax.device_put(pages,
                                   page_pool_shardings(pages, self.mesh))
            state = self._place_cache(state)
        return pages, state

    def paged_decode(self, pages, state, table: np.ndarray,
                     tokens: np.ndarray, pos: np.ndarray, active: np.ndarray,
                     *, max_seq: int, page_size: int, head_params=None):
        """One paged decode tick: gather per-slot views through the page
        table, splice in the recurrent state, run the *same* compiled decode
        step the contiguous engine uses (that identity is the bitwise-parity
        argument), then commit the written position back to the arenas and
        re-extract the state.  ``pages``/``state`` are consumed (the view —
        and with it the spliced-in state buffers — is donated to decode, and
        commit donates the arena); rebind to the returned pair."""
        from repro.models.model import extract_paged_state, merge_paged_view
        if head_params is None:
            head_params = self.head.params
        fns = self._paged_fns(max_seq, page_size)
        pt = jnp.asarray(table, jnp.int32)
        posj = jnp.asarray(pos, jnp.int32)
        view = fns.gather(pages, pt)
        full = merge_paged_view(self.cfg, view, state)
        logits, new_full = self._decode(
            self.params, full, jnp.asarray(tokens[:, None], jnp.int32),
            posj, head_params=head_params, active=jnp.asarray(active))
        new_pages = fns.commit(pages, new_full, pt, posj)
        new_state = extract_paged_state(self.cfg, new_full)
        return np.asarray(logits), new_pages, new_state

    def paged_insert(self, pages, filled, pt_rows: np.ndarray, *,
                     max_seq: int, page_size: int):
        """Scatter freshly prefilled rows into newly mapped pages (``pages``
        donated; ``filled`` is also read by the state insert — not donated)."""
        fns = self._paged_fns(max_seq, page_size)
        return fns.insert(pages, filled, jnp.asarray(pt_rows, jnp.int32))

    def page_copy(self, pages, src_ids: np.ndarray, dst_ids: np.ndarray, *,
                  max_seq: int, page_size: int):
        """COW fork: copy pages ``src_ids → dst_ids`` in every arena."""
        fns = self._paged_fns(max_seq, page_size)
        return fns.page_copy(pages, jnp.asarray(src_ids, jnp.int32),
                             jnp.asarray(dst_ids, jnp.int32))

    def state_rows(self, filled, row: int):
        """One request's recurrent-state rows as a host numpy tree — what a
        prefix-cache entry stores (constant-size; no pages involved).
        ``None`` for archs with no recurrent layers."""
        from repro.models.model import extract_state_rows
        rows = extract_state_rows(self.cfg, filled, row)
        if not jax.tree_util.tree_leaves(rows):
            return None
        return jax.tree.map(lambda x: np.asarray(x), rows)

    def state_restore(self, state, entry_state, slot: int):
        """Insert a prefix entry's stored recurrent rows into one slot."""
        src = jax.tree.map(jnp.asarray, entry_state)
        return self._insert(state, src, jnp.asarray([slot], jnp.int32))

    def expand_rows(self, filled, inv: np.ndarray):
        """Expand a deduped prefill — (G_unique, …) rows → (G, …) via the
        inverse index — so slot inserts stay one-row-per-request."""
        from repro.launch.steps import expand_rows_fn
        return expand_rows_fn(self.cfg)(filled, jnp.asarray(inv, jnp.int32))

    def megastep(self, pool, tokens: np.ndarray, pos: np.ndarray,
                 active: np.ndarray, key, k: int, sampler: Sampler,
                 eos_id: Optional[int], head_params=None):
        """K decode steps + in-scan sampling/EOS retirement in one dispatch
        (launch/decode_loop.py).  ``pool`` is donated; only the (k, B) token
        block and the small carry vectors cross back to host."""
        from repro.launch.decode_loop import jitted_megastep

        if head_params is None:
            head_params = self.head.params
        fn = jitted_megastep(self.cfg, self.head.without_params(), sampler,
                             k, mesh=self.mesh, eos_id=eos_id, masked=True)
        block, pool, last_tok, pos, active, key = fn(
            self.params, pool, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), key,
            head_params=head_params, active=jnp.asarray(active))
        # np.array (not asarray): the engine mutates pos/last_tok per slot
        # on admission, and zero-copy views of jax arrays are read-only.
        return (np.asarray(block), pool, np.array(last_tok, np.int32),
                np.array(pos, np.int32), np.asarray(active), key)

    def spec_megastep(self, pool, tokens: np.ndarray, pos: np.ndarray,
                      active: np.ndarray, key, k: int, sampler: Sampler,
                      eos_id: Optional[int]):
        """One speculative two-head dispatch: the engine's head drafts ``k``
        tokens, one batched dense pass verifies, and ``m`` lockstep-commit
        (DESIGN.md §11).  ``pool`` is donated.  Returns the (k, B) verify
        block, the committed step count ``m`` (host int), the per-slot
        accepted-draft counts, and the rewound carry."""
        from repro.launch.decode_loop import jitted_spec_megastep

        fn = jitted_spec_megastep(self.cfg, self.head.without_params(),
                                  sampler, k, mesh=self.mesh, eos_id=eos_id,
                                  masked=True)
        block, m, acc, _adv, pool, last_tok, pos, active, key = fn(
            self.params, pool, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(pos, jnp.int32), key,
            head_params=self.head.params, active=jnp.asarray(active))
        return (np.asarray(block), int(jax.device_get(m)), np.asarray(acc),
                pool, np.array(last_tok, np.int32), np.array(pos, np.int32),
                np.asarray(active), key)


class ServeEngine:
    """Continuous-batching engine over a ``backend`` and ``n_slots`` cache rows.

    ``submit()`` requests, then ``run()`` (or ``step()`` tick by tick);
    finished sequences land in ``finished[rid]`` as the generated token list
    (prompt excluded).  Token selection is the ``sampler``
    (repro.api.Sampler; greedy by default, otherwise a key chain seeded once
    — reproducible per seed).
    """

    def __init__(self, backend, n_slots: int, max_seq: int, *,
                 eos_id: Optional[int] = None,
                 sampler: Optional[Sampler] = None, decode_chunk: int = 1,
                 spec_decode: int = 0, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 head_cache=None, greedy=None, seed=None):
        _, sampler = resolve_legacy_serving_kwargs(
            None, sampler, None, None, None, greedy, seed, "ServeEngine")
        if head_cache is not None and spec_decode:
            raise ValueError("spec_decode and per-tenant heads are mutually "
                             "exclusive: the draft/verify megastep re-reads "
                             "the head inside its scan and cannot re-gather "
                             "per-slot tenant bindings mid-draft")
        if decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
        if spec_decode < 0:
            raise ValueError(f"spec_decode must be >= 0, got {spec_decode}")
        if spec_decode and decode_chunk > 1:
            raise ValueError("spec_decode and decode_chunk > 1 are mutually "
                             "exclusive: the speculative megastep already "
                             "advances up to K tokens per tick")
        if spec_decode and not hasattr(backend, "spec_megastep"):
            raise ValueError("spec_decode needs a backend with a "
                             "spec_megastep (the fused draft/verify "
                             "dispatch); this backend has none")
        if paged:
            if decode_chunk > 1:
                raise ValueError("paged=True runs the host decode loop; "
                                 "decode_chunk > 1 is not supported yet")
            if spec_decode:
                raise ValueError("paged=True and spec_decode are mutually "
                                 "exclusive")
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if not hasattr(backend, "init_paged"):
                raise ValueError("paged=True needs a backend with the paged "
                                 "pool ops (init_paged/paged_decode/…); "
                                 "this backend has none")
        self.backend = backend
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.sampler = sampler or Sampler()
        self.decode_chunk = decode_chunk
        self.spec_decode = spec_decode
        self.paged = paged
        self.page_size = page_size
        if paged:
            from repro.launch.paging import PagePool, PrefixCache
            npp = -(-max_seq // page_size)          # page-table width
            if num_pages is None:
                # Enough for every slot's full budget plus a prefix-cache
                # working set; LRU eviction absorbs the heavy tail beyond.
                num_pages = 1 + (n_slots + 8) * (npp + 1)
            self.pages, self.state = backend.init_paged(
                n_slots, max_seq, page_size, num_pages)
            self.page_pool = PagePool(num_pages, n_slots, npp)
            self.prefix = PrefixCache(self.page_pool)
            self._geoms = backend.paged_geometries(max_seq)
            self._has_state = bool(jax.tree_util.tree_leaves(self.state))
            self.pool = None
        else:
            self.pool = backend.init_pool(n_slots, max_seq)
        self.head_cache = head_cache
        self.slot_tenant: List[Optional[object]] = [None] * n_slots
        self._refresh: Dict = {}           # tenant -> f32 working head copy
        self.sched = SlotScheduler(n_slots)
        self.pos = np.zeros(n_slots, np.int32)         # tokens cached per slot
        self.last_tok = np.zeros(n_slots, np.int32)    # sampled, not yet cached
        self.remaining = np.zeros(n_slots, np.int32)   # tokens still to emit
        self.queue = RequestQueue()        # arrival-ordered, FIFO on ties
        self.outputs: Dict[int, List[int]] = {}
        self.finished: Dict[int, List[int]] = {}
        self.now = 0                                   # engine tick clock
        self._next_rid = 0
        self._rids: set[int] = set()                   # every rid ever submitted
        self._pending_reset: List[int] = []            # slots retired this tick
        self._key = self.sampler.init_key()
        self.stats = {"refreshes": 0, "publishes": 0,
                      "decode_steps": 0, "active_slot_steps": 0,
                      "admitted": 0, "retired": 0, "prefill_batches": 0,
                      "megasteps": 0, "host_syncs": 0, "verify_calls": 0,
                      "draft_tokens": 0, "accepted_draft_tokens": 0,
                      "dedup_saved": 0, "prefix_hits": 0,
                      "prefix_queries": 0, "page_allocs": 0,
                      "cow_copies": 0, "pages_in_use": 0,
                      "pages_in_use_peak": 0}

    # -- request intake ----------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *, arrival: int = 0,
               rid: Optional[int] = None, tenant=None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("prompt must be non-empty")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.head_cache is not None and tenant is None:
            raise ValueError("this engine serves per-tenant heads "
                             "(head_cache=); every submit needs tenant=")
        if self.head_cache is None and tenant is not None:
            raise ValueError("tenant= needs a per-tenant engine — pass "
                             "head_cache= to make_engine/ServeEngine")
        if len(prompt) + max_new_tokens > self.max_seq + 1:
            # The last sampled token is never written back to the cache.
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the engine's max_seq ({self.max_seq})")
        if rid is None:
            rid = self._next_rid
        if rid in self._rids:
            raise ValueError(f"request id {rid} already submitted")
        self._rids.add(rid)
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.push(Request(rid, prompt, max_new_tokens, arrival, tenant))
        return rid

    # -- scheduling --------------------------------------------------------

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        self._key, toks = self.sampler.sample(self._key, logits)
        self.stats["host_syncs"] += 1
        return np.asarray(toks, np.int32)

    def _pop_admission_batch(self) -> List[Request]:
        batch: List[Request] = []
        while (self.queue and self.queue.peek().arrival <= self.now
               and self.sched.n_free > len(batch)):
            batch.append(self.queue.pop())
        return batch

    @staticmethod
    def _by_len(batch: List[Request]) -> Dict[int, List[Request]]:
        by_len: Dict[int, List[Request]] = {}
        for r in batch:
            by_len.setdefault(len(r.prompt), []).append(r)
        return by_len

    def _bind_tenants(self, group: List[Request], slots: np.ndarray) -> None:
        """Pin each admitted request's tenant resident in the HeadCache and
        record the slot→tenant binding.  Runs *before* ``_finish_admit``:
        a request that retires immediately (budget 1 / first-token EOS)
        releases its pin inside ``_retire``, so acquire must come first."""
        if self.head_cache is None:
            return
        for r, s in zip(group, slots):
            self.head_cache.acquire(r.tenant)
            self.slot_tenant[int(s)] = r.tenant

    def _finish_admit(self, group: List[Request], slots: np.ndarray,
                      first: np.ndarray, plen: int) -> None:
        """Shared per-request admission bookkeeping (both pool layouts)."""
        self.stats["admitted"] += len(group)
        for i, r in enumerate(group):
            s = int(slots[i])
            self.pos[s] = plen
            self.last_tok[s] = first[i]
            self.remaining[s] = r.max_new_tokens - 1
            self.outputs[r.rid] = [int(first[i])]
            if (self.remaining[s] == 0
                    or (self.eos_id is not None
                        and int(first[i]) == self.eos_id)):
                self._retire(s)

    def _admit(self) -> None:
        """FIFO head-of-line admission into free slots; equal-length prompts
        arriving together prefill as one batch (the bulk-prefill path), and
        *identical* prompts in that batch prefill once (deduped — their
        logits/cache rows are expanded back to one per request)."""
        if self.paged:
            return self._admit_paged()
        batch = self._pop_admission_batch()
        for plen, group in self._by_len(batch).items():
            uniq: Dict[bytes, int] = {}
            rows: List[np.ndarray] = []
            inv: List[int] = []
            for r in group:
                key = r.prompt.tobytes()
                if key not in uniq:
                    uniq[key] = len(rows)
                    rows.append(r.prompt)
                inv.append(uniq[key])
            prompts = jnp.asarray(np.stack(rows))
            logits, filled = self.backend.prefill(prompts, self.max_seq)
            if len(rows) < len(group):
                inv_arr = np.asarray(inv)
                logits = logits[inv_arr]
                filled = (self.backend.expand_rows(filled, inv_arr)
                          if hasattr(self.backend, "expand_rows")
                          else jax.tree.map(lambda x: x[inv_arr], filled))
                self.stats["dedup_saved"] += len(group) - len(rows)
            # ONE sample over the full (G, V) group — the sampler splits its
            # key once per call, so deduping must not change the call count.
            first = self._sample(logits)
            slots = np.asarray([self.sched.admit(r.rid) for r in group])
            self._bind_tenants(group, slots)
            # A slot freed by an immediate retirement earlier in this same
            # admission round may be handed out again here; drop its pending
            # reset — the insert fully overwrites the row, and a deferred
            # reset would clobber the new request's cache at end of tick.
            self._pending_reset = [s for s in self._pending_reset
                                   if s not in slots]
            self.pool = self.backend.insert(self.pool, filled, slots)
            self.stats["prefill_batches"] += 1
            self._finish_admit(group, slots, first, plen)

    def _admit_paged(self) -> None:
        """Paged admission: exact-prompt prefix-cache hits map the entry's
        shared pages copy-free (COW via refcounts) and restore its stored
        recurrent state + first-token logits; misses bulk-prefill once per
        unique prompt, scatter into freshly allocated pages, and register a
        new entry.  The sampler still sees exactly one (G, V) call per
        prompt-length group, in the same group order as the contiguous
        engine — that keeps the seeded key chain aligned across layouts."""
        batch = self._pop_admission_batch()
        for plen, group in self._by_len(batch).items():
            # Classify in arrival order: hit / dup-of-miss / unique miss.
            plans = []                     # (request, kind, key, ref)
            miss_rows: List[np.ndarray] = []
            seen_miss: Dict[bytes, int] = {}
            for r in group:
                key = r.prompt.tobytes()
                entry = self.prefix.get(key)
                if entry is not None:
                    plans.append((r, "hit", key, entry))
                elif key in seen_miss:
                    plans.append((r, "dup", key, seen_miss[key]))
                    self.stats["dedup_saved"] += 1
                else:
                    seen_miss[key] = len(miss_rows)
                    miss_rows.append(r.prompt)
                    plans.append((r, "miss", key, seen_miss[key]))
            logits_u = filled = None
            if miss_rows:
                prompts = jnp.asarray(np.stack(miss_rows))
                logits_u, filled = self.backend.prefill(prompts, self.max_seq)
                self.stats["prefill_batches"] += 1
            # ONE sample per group over rows assembled in arrival order
            # (stored-entry logits for hits, fresh prefill rows otherwise).
            first = self._sample(np.stack(
                [p[3].logits if p[1] == "hit" else logits_u[p[3]]
                 for p in plans]))
            slots = np.asarray([self.sched.admit(r.rid) for r in group])
            self._bind_tenants(group, slots)
            self._pending_reset = [s for s in self._pending_reset
                                   if s not in slots]
            # Wire pages + state.  Misses first: allocate/map fresh pages,
            # one scatter for all their rows, then register prefix entries.
            n_alloc = -(-plen // self.page_size)
            miss_slots, miss_pt = [], []
            for p, slot in zip(plans, slots):
                if p[1] != "miss":
                    continue
                ids = self._alloc_pages(n_alloc)
                self.page_pool.map_slot(int(slot), ids, owned=True)
                miss_slots.append(int(slot))
                miss_pt.append(self.page_pool.table[int(slot)].copy())
            if miss_slots:
                self.pages = self.backend.paged_insert(
                    self.pages, filled, np.stack(miss_pt),
                    max_seq=self.max_seq, page_size=self.page_size)
                if self._has_state:
                    self.state = self.backend.insert(
                        self.state, filled, np.asarray(miss_slots))
                for p, slot in zip(plans, slots):
                    if p[1] == "miss":
                        self.prefix.register(
                            p[2], self.page_pool.slot_pages(int(slot)),
                            self.backend.state_rows(filled, p[3]),
                            logits_u[p[3]], plen)
            # Hits and dups share the entry's pages (refcounted → COW on
            # first divergent decode write) and restore its state rows.
            for p, slot in zip(plans, slots):
                if p[1] == "miss":
                    continue
                entry = (p[3] if p[1] == "hit"
                         else self.prefix.peek(p[2]))
                self.page_pool.map_slot(int(slot), entry.page_ids,
                                        owned=False)
                if entry.state is not None:
                    self.state = self.backend.state_restore(
                        self.state, entry.state, int(slot))
            self._finish_admit(group, slots, first, plen)
        self._sync_page_stats()

    def _alloc_pages(self, n: int) -> List[int]:
        """Allocate ``n`` pages, evicting LRU prefix entries until they fit."""
        while True:
            ids = self.page_pool.alloc(n)
            if ids is not None:
                return ids
            if not self.prefix.evict_lru():
                raise RuntimeError(
                    f"page pool exhausted: {n} pages requested, "
                    f"{self.page_pool.n_free} free and nothing left to "
                    f"evict — raise num_pages or lower n_slots/max_seq")

    def _ensure_write_pages(self, active_slots: List[int]) -> None:
        """Before a decode tick, make every active slot's write page private
        and mapped: unmapped → allocate; shared (refcount > 1, i.e. a prefix
        entry or sibling slot also references it) → copy-on-write fork.
        The COW here is what makes prefix sharing *correct*, not just fast —
        without it the first divergent token would corrupt siblings."""
        copies = []                         # (src, dst) page-id pairs
        for s in active_slots:
            pos = int(self.pos[s])
            idxs = {(pos % size if ring else pos) // self.page_size
                    for size, ring in self._geoms}
            for j in sorted(idxs):
                pid = int(self.page_pool.table[s, j])
                if pid == 0:
                    (new,) = self._alloc_pages(1)
                    self.page_pool.map_index(s, j, new)
                elif self.page_pool.refcount[pid] > 1:
                    (new,) = self._alloc_pages(1)
                    self.page_pool.remap(s, j, new)
                    copies.append((pid, new))
                    self.stats["cow_copies"] += 1
        if copies:
            # One fixed-shape scatter for all forks this tick, padded with
            # (0, 0) — copying the zero page onto itself is a no-op.
            cap = self.n_slots * max(1, len(self._geoms))
            assert len(copies) <= cap
            pairs = copies + [(0, 0)] * (cap - len(copies))
            self.pages = self.backend.page_copy(
                self.pages, np.asarray([p[0] for p in pairs], np.int32),
                np.asarray([p[1] for p in pairs], np.int32),
                max_seq=self.max_seq, page_size=self.page_size)

    def _sync_page_stats(self) -> None:
        self.stats["page_allocs"] = self.page_pool.page_allocs
        self.stats["pages_in_use"] = self.page_pool.pages_in_use
        self.stats["pages_in_use_peak"] = self.page_pool.peak_in_use
        self.stats["prefix_hits"] = self.prefix.hits
        self.stats["prefix_queries"] = self.prefix.queries

    def _retire(self, slot: int) -> None:
        rid = self.sched.retire(slot)
        self.finished[rid] = self.outputs[rid]
        if self.head_cache is not None and self.slot_tenant[slot] is not None:
            self.head_cache.release(self.slot_tenant[slot])
            self.slot_tenant[slot] = None
        # Resets are batched per tick (one jitted call for all retirements
        # this step) — a freed row is never read while inactive, and
        # ``slot_insert`` fully overwrites it on re-admission.
        self._pending_reset.append(slot)
        if self.paged:
            # Unmap the slot's pages (prefix entries keep shared ones alive;
            # exclusively owned ones return to the free list).
            self.page_pool.clear_slot(slot)
        self.stats["retired"] += 1

    # -- per-tenant heads (DESIGN.md §14) ----------------------------------

    def _head_params_now(self):
        """This tick's decode head params: the HeadCache bank plus the
        slot→bank-row binding (``None`` on single-tenant engines — the
        backend then serves its own bound ``head.params``).  Free slots
        point at bank row 0; their logits are masked/ignored anyway."""
        if self.head_cache is None:
            return None
        ids = np.zeros(self.n_slots, np.int32)
        for s, t in enumerate(self.slot_tenant):
            if t is not None:
                ids[s] = self.head_cache.slot(t)
        return self.head_cache.bank_params(ids)

    def refresh(self, tenant, hidden, *, targets=None, alphas=None,
                lr: float = 1.0) -> None:
        """Fold live-traffic (hidden, logit) pairs into ``tenant``'s head
        online (``kernels/race_update``; DESIGN.md §14).

        Accumulates into a host-held f32 working copy — the *shadow* buffer
        of the double-buffered scheme; in-flight and subsequent decodes keep
        reading the published bank row bitwise unchanged until
        :meth:`publish` commits.  Exactly one of ``alphas`` ((M, V) direct
        representer weights) or ``targets`` ((M, V) teacher logits for the
        residual fold, scaled by ``lr``) must be given; the tenant must be
        resident (acquired at least once).
        """
        if self.head_cache is None:
            raise ValueError("refresh needs a per-tenant engine — pass "
                             "head_cache= to make_engine/ServeEngine")
        from repro.core.sketch_lm_head import dequantize_head, refresh_head
        spec = self.backend.head
        if tenant not in self._refresh:
            self._refresh[tenant] = dequantize_head(
                self.head_cache.tenant_params(tenant), spec.quant)
        self._refresh[tenant] = refresh_head(
            self._refresh[tenant], spec.cfg, hidden,
            targets=targets, alphas=alphas, lr=lr)
        self.stats["refreshes"] += 1

    def publish(self, tenant) -> None:
        """Commit ``tenant``'s pending refreshes: re-quantize the f32
        working copy to the head's storage mode and swap it into the bank
        between ticks.  Re-quantization happens here, not per refresh —
        repeated int8/int4 round-trips would compound rounding error, so
        the shadow stays f32 until the publish."""
        if tenant not in self._refresh:
            raise ValueError(f"no pending refresh for tenant {tenant!r}; "
                             f"call engine.refresh(...) first")
        from repro.core.sketch_lm_head import quantize_head
        params = quantize_head(self._refresh.pop(tenant),
                               self.backend.head.quant)
        self.head_cache.publish(tenant, params)
        self.stats["publishes"] += 1

    # -- the engine tick ---------------------------------------------------

    def _chunk_for(self, active_slots: List[int],
                   base: Optional[int] = None) -> int:
        """The megastep length for this tick: ``base`` (``decode_chunk``, or
        the speculative draft length) clamped so no occupied slot overshoots
        its budget (its remaining tokens) and — when a slot is free to admit
        into — no queued arrival is kept waiting past its arrival tick."""
        chunk = min(base or self.decode_chunk,
                    int(min(self.remaining[s] for s in active_slots)))
        if self.queue and self.sched.n_free:
            chunk = min(chunk, max(1, self.queue.peek().arrival - self.now))
        return max(1, chunk)

    def _decode_megastep(self, active_slots: List[int], chunk: int) -> None:
        """Advance every occupied slot ``chunk`` tokens in one device
        dispatch, then walk the returned (chunk, B) block for per-slot
        retirement (EOS mid-chunk rows are frozen in-scan; their trailing
        block entries are padding and are skipped here)."""
        active = np.zeros(self.n_slots, bool)
        active[active_slots] = True
        hp = self._head_params_now()
        kw = {} if hp is None else {"head_params": hp}
        if hasattr(self.backend, "megastep"):
            (block, self.pool, self.last_tok, self.pos, _,
             self._key) = self.backend.megastep(
                self.pool, self.last_tok, self.pos, active, self._key,
                chunk, self.sampler, self.eos_id, **kw)
            # One block fetch per dispatch; the emulated path below counts
            # its per-token syncs inside _sample instead.
            self.stats["host_syncs"] += 1
        else:
            block = self._emulate_megastep(active, chunk)
        self.stats["decode_steps"] += chunk
        self.stats["megasteps"] += 1
        for s in active_slots:
            for i in range(chunk):
                tok = int(block[i, s])
                self.outputs[self.sched.owner[s]].append(tok)
                self.remaining[s] -= 1
                self.stats["active_slot_steps"] += 1
                if (self.remaining[s] == 0
                        or (self.eos_id is not None and tok == self.eos_id)):
                    self._retire(s)
                    break

    def _decode_spec_megastep(self, active_slots: List[int],
                              draft_k: int) -> int:
        """One speculative tick: draft ``draft_k`` tokens through the
        engine's head, dense-verify the block, and commit the ``m``
        lockstep-accepted steps — then walk the committed rows exactly like
        ``_decode_megastep`` (EOS mid-block retires; trailing entries of a
        retired row are padding).  Returns ``m`` (the tick clock advance)."""
        active = np.zeros(self.n_slots, bool)
        active[active_slots] = True
        (block, m, acc, self.pool, self.last_tok, self.pos, _,
         self._key) = self.backend.spec_megastep(
            self.pool, self.last_tok, self.pos, active, self._key,
            draft_k, self.sampler, self.eos_id)
        self.stats["host_syncs"] += 1
        self.stats["decode_steps"] += draft_k      # backbone (draft) steps
        self.stats["megasteps"] += 1
        self.stats["verify_calls"] += 1
        self.stats["draft_tokens"] += draft_k * len(active_slots)
        self.stats["accepted_draft_tokens"] += int(acc[active_slots].sum())
        for s in active_slots:
            for i in range(m):
                tok = int(block[i, s])
                self.outputs[self.sched.owner[s]].append(tok)
                self.remaining[s] -= 1
                self.stats["active_slot_steps"] += 1
                if (self.remaining[s] == 0
                        or (self.eos_id is not None and tok == self.eos_id)):
                    self._retire(s)
                    break
        return m

    def _emulate_megastep(self, active: np.ndarray, chunk: int) -> np.ndarray:
        """Host-loop emulation of the fused megastep for backends without
        one (e.g. the numpy fake in the property tests): same step→sample→
        mask→retire sequence, one backend.decode per token."""
        active = active.copy()
        block = np.zeros((chunk, self.n_slots), np.int32)
        hp = self._head_params_now()
        kw = {} if hp is None else {"head_params": hp}
        for i in range(chunk):
            step_active = active.copy()
            logits, self.pool = self.backend.decode(
                self.pool, self.last_tok, self.pos, step_active, **kw)
            nxt = np.where(step_active, self._sample(logits), 0).astype(
                np.int32)
            if self.eos_id is not None:
                active &= nxt != self.eos_id
            block[i] = nxt
            self.pos += step_active.astype(np.int32)
            self.last_tok = nxt
        return block

    def step(self) -> None:
        """One tick: admit into free slots, then decode every occupied slot
        — one token (``decode_chunk=1``, the bitwise-parity default) or a
        ``decode_chunk``-clamped megastep block."""
        self._admit()
        active_slots = self.sched.active_slots()
        advanced = 1
        if active_slots and self.spec_decode:
            draft_k = self._chunk_for(active_slots, base=self.spec_decode)
            advanced = self._decode_spec_megastep(active_slots, draft_k)
        elif active_slots and self.decode_chunk > 1:
            advanced = self._chunk_for(active_slots)
            self._decode_megastep(active_slots, advanced)
        elif active_slots:
            active = np.zeros(self.n_slots, bool)
            active[active_slots] = True
            hp = self._head_params_now()
            kw = {} if hp is None else {"head_params": hp}
            if self.paged:
                self._ensure_write_pages(active_slots)
                logits, self.pages, self.state = self.backend.paged_decode(
                    self.pages, self.state, self.page_pool.table,
                    self.last_tok, self.pos, active,
                    max_seq=self.max_seq, page_size=self.page_size, **kw)
            else:
                logits, self.pool = self.backend.decode(
                    self.pool, self.last_tok, self.pos, active, **kw)
            nxt = self._sample(logits)
            self.stats["decode_steps"] += 1
            self.stats["megasteps"] += 1
            self.stats["active_slot_steps"] += len(active_slots)
            for s in active_slots:
                tok = int(nxt[s])
                self.outputs[self.sched.owner[s]].append(tok)
                self.pos[s] += 1
                self.last_tok[s] = tok
                self.remaining[s] -= 1
                if (self.remaining[s] == 0
                        or (self.eos_id is not None and tok == self.eos_id)):
                    self._retire(s)
        if self._pending_reset:
            # Pad to a fixed (n_slots,) shape so the jitted reset compiles
            # once; duplicate indices write the same zeros, so padding with
            # the first slot is a no-op.
            slots = self._pending_reset + [self._pending_reset[0]] * (
                self.n_slots - len(self._pending_reset))
            if self.paged:
                # Pages were unmapped at retirement (the arena needs no
                # zeroing — unmapped gathers read the reserved zero page);
                # only the recurrent state rows are zeroed.
                if self._has_state:
                    self.state = self.backend.reset(self.state,
                                                    np.asarray(slots))
            else:
                self.pool = self.backend.reset(self.pool, np.asarray(slots))
            self._pending_reset.clear()
        if self.paged:
            self._sync_page_stats()
        self.now += advanced

    def run(self) -> Dict[int, List[int]]:
        """Tick until the queue drains and every slot retires."""
        while self.queue or self.sched.n_active:
            if not self.sched.n_active and self.queue.peek().arrival > self.now:
                self.now = self.queue.peek().arrival  # idle: jump to arrival
            self.step()
        return self.finished

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        steps = self.stats["decode_steps"]
        return (self.stats["active_slot_steps"] / (steps * self.n_slots)
                if steps else 0.0)


def make_engine(params, cfg: ModelConfig, n_slots: int, max_seq: int, *,
                head: Optional[LogitHead] = None,
                sampler: Optional[Sampler] = None,
                eos_id: Optional[int] = None, mesh=None,
                decode_chunk: int = 1, spec_decode: int = 0,
                paged: bool = False, page_size: int = 16,
                num_pages: Optional[int] = None, head_cache=None,
                sketch_head=None, sketch_cfg: Optional[SketchHeadConfig] = None,
                fused=None, greedy=None, seed=None) -> ServeEngine:
    """Engine over a real model: the serving entry point (see launch.serve
    and the ``LM.engine`` / ``LM.serve`` facade).  ``mesh`` makes the whole
    engine SPMD-sharded: the slot pool's cache rows batch-shard over
    ``data``, head count arrays over ``model``, and the slot ops preserve
    those shardings across insert/reset (DESIGN.md §9).  ``decode_chunk=K``
    decodes K tokens per occupied slot between admission rounds in one
    on-device megastep (launch/decode_loop.py, DESIGN.md §10); the default
    1 keeps the per-token tick, bitwise-identical to the pre-megastep
    engine.  ``spec_decode=K`` makes every tick a speculative two-head
    megastep instead: the engine's ``head`` drafts K tokens and one batched
    dense pass verifies them, emitting the dense stream bitwise (DESIGN.md
    §11; mutually exclusive with ``decode_chunk > 1``).  ``paged=True``
    swaps the fixed per-slot pool for the paged arena + prefix cache
    (DESIGN.md §13): slots map ``page_size``-token pages through a
    refcounted page table, identical prompts hit the prefix cache instead
    of re-prefilling, and shared pages fork copy-on-write on the first
    divergent decode write — token streams stay bitwise identical to the
    contiguous engine.  ``head_cache=`` (a ``repro.api.HeadCache``) makes
    the engine *per-tenant* (DESIGN.md §14): ``head`` becomes the shared
    sketch spec (config/backend/quant) while each slot decodes through its
    request's tenant's arrays, paged in/out of the cache on demand; every
    ``submit`` then needs ``tenant=``, and ``engine.refresh(tenant, ...)``
    / ``engine.publish(tenant)`` fold live traffic into a tenant's head
    online.  The pre-redesign
    ``sketch_head=/sketch_cfg=/fused=/greedy=/seed=`` kwargs keep working
    behind a DeprecationWarning."""
    head, sampler = resolve_legacy_serving_kwargs(
        head, sampler, sketch_head, sketch_cfg, fused, greedy, seed,
        "make_engine")
    if head_cache is not None:
        from repro.api.heads import SketchHead
        if not isinstance(head, SketchHead):
            raise ValueError(
                "head_cache= (per-tenant serving) needs a SketchHead spec "
                f"for head=; got {type(head).__name__ if head is not None else None}")
        head = dataclasses.replace(head.without_params(), per_tenant=True)
        if head_cache.mesh is None:
            head_cache.mesh = mesh
    backend = EngineBackend(params, cfg, head=head, mesh=mesh)
    return ServeEngine(backend, n_slots, max_seq, eos_id=eos_id,
                       sampler=sampler, decode_chunk=decode_chunk,
                       spec_decode=spec_decode, paged=paged,
                       page_size=page_size, num_pages=num_pages,
                       head_cache=head_cache)
