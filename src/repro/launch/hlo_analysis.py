"""Post-optimization HLO analyzer: FLOPs / bytes / collectives with
while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts every while (scan) body exactly ONCE
(verified empirically — a 10-trip scan of a 128³ matmul reports 1×, not
10×), which would understate a scanned-layer transformer by n_layers×.
This module re-derives the roofline numerators from ``compiled.as_text()``:

* parses every computation, building a name → shape map per computation,
* reads the **known_trip_count** backend_config off every ``while`` op and
  propagates multipliers through the call graph
  (entry → while bodies → nested scans → fusion subcomputations),
* FLOPs:  ``dot`` = 2·prod(out)·prod(contracted dims); elementwise
  arithmetic and reduces = prod(shape) (VPU estimate),
* bytes:  per *scheduled* op in control computations — output + operands
  (fusions count as single ops: their operands/outputs are the HBM
  traffic, interior ops are register/VMEM traffic),
* collectives: bytes by kind (all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute), trip-weighted.

All shapes in the SPMD-partitioned module are per-device shards, so every
number this module returns is **per device** — exactly what the roofline
terms need.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                     r"([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*"
                          r"(?:->\s*.*?)?\s*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|"
                        r"false_computation)=\{?%?([\w.\-,% ]+)\}?")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil", "round",
    "cosine", "sine", "logistic", "erf", "atan2", "remainder", "cbrt",
    "select", "clamp", "compare", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_BYTE_SKIP = {"tuple", "get-tuple-element", "parameter", "constant",
              "bitcast", "while", "conditional", "call", "after-all",
              "opt-barrier", "partition-id", "replica-id", "iota"}


def _shape_elems(sig: str) -> List[Tuple[str, int]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def _shape_bytes(sig: str) -> int:
    return sum(n * _DTYPE_BYTES[d] for d, n in _shape_elems(sig))


def _shape_bytes_bf16adj(sig: str) -> int:
    """Bytes with f32 counted at 2 B/elem — the XLA CPU backend legalizes
    bf16 arithmetic to f32 *before* this HLO is printed, so on the TPU
    target these tensors are bf16.  (True-f32 tensors — optimizer moments,
    softmax stats — are a small fraction of per-step traffic; the raw and
    adjusted numbers bracket the deployment value.)"""
    return sum(n * (2 if d == "f32" else _DTYPE_BYTES[d])
               for d, n in _shape_elems(sig))


def _shape_count(sig: str) -> int:
    return sum(n for _, n in _shape_elems(sig))


class Op:
    __slots__ = ("name", "out_sig", "opcode", "rest")

    def __init__(self, name, out_sig, opcode, rest):
        self.name, self.out_sig, self.opcode, self.rest = (
            name, out_sig, opcode, rest)


def _parse_computations(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            # Header: `name (args) -> ret {` — never an op definition
            # (op defs match _DEF_RE: `%x = shape opcode(`).
            if s.endswith("{") and not _DEF_RE.match(line):
                m = _COMP_HDR_RE.match(s)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        d = _DEF_RE.match(line)
        if d:
            name, out_sig, opcode = d.groups()
            rest = line[d.end():]
            comps[cur].append(Op(name, out_sig, opcode, rest))
    return comps


def _entry_name(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1)


def analyze(text: str, top_ops: int = 0) -> dict:
    comps = _parse_computations(text)
    entry = _entry_name(text)

    # ---- multipliers through the call graph -------------------------------
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # worklist DFS; HLO call graphs are acyclic
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        m = mult[comp]
        for op in comps.get(comp, ()):
            children: List[Tuple[str, float]] = []
            trip = 1.0
            t = _TRIP_RE.search(op.rest)
            if op.opcode == "while":
                if t:
                    trip = float(t.group(1))
                b = _BODY_RE.search(op.rest)
                c = _COND_RE.search(op.rest)
                if b:
                    children.append((b.group(1), m * trip))
                if c:
                    children.append((c.group(1), m * (trip + 1)))
            else:
                for rx in (_CALLS_RE, _TO_APPLY_RE):
                    mm = rx.search(op.rest)
                    if mm:
                        children.append((mm.group(1), m))
                mb = _BRANCH_RE.search(op.rest)
                if mb:
                    for name in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                        if name in comps:
                            children.append((name, m))
            for child, cm in children:
                mult[child] += cm
                if child not in seen:
                    seen.add(child)
                    order.append(child)

    # ---- per-computation shape maps ---------------------------------------
    shape_of: Dict[str, Dict[str, str]] = {
        c: {op.name: op.out_sig for op in ops} for c, ops in comps.items()}

    flops = 0.0
    elementwise_flops = 0.0
    bytes_accessed = 0.0
    bytes_bf16adj = 0.0
    coll: Dict[str, float] = defaultdict(float)
    flop_items: List[Tuple[float, str, str, str]] = []

    # computations reached via fusion `calls=` are interior (no byte count)
    interior = set()
    for c, ops in comps.items():
        for op in ops:
            if op.opcode == "fusion":
                mm = _CALLS_RE.search(op.rest)
                if mm:
                    interior.add(mm.group(1))

    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        smap = shape_of[comp]
        for op in ops:
            # FLOPs
            if op.opcode == "dot":
                operands = _OPERAND_RE.findall(op.rest)
                lhs_sig = smap.get(operands[0], "") if operands else ""
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                contracted = 1
                if lhs_sig and cdims:
                    dims_m = _SHAPE_RE.search(lhs_sig)
                    if dims_m:
                        lhs_dims = [int(x) for x in
                                    dims_m.group(2).split(",") if x]
                        for ci in cdims.group(1).split(","):
                            if ci:
                                contracted *= lhs_dims[int(ci)]
                f = m * 2.0 * _shape_count(op.out_sig) * contracted
                flops += f
                if top_ops:
                    meta = re.search(r'op_name="([^"]*)"', op.rest)
                    flop_items.append(
                        (f, comp, op.out_sig[:60],
                         meta.group(1)[-90:] if meta else op.name))
            elif op.opcode in _ELEMENTWISE:
                elementwise_flops += m * _shape_count(op.out_sig)
            elif op.opcode == "reduce":
                operands = _OPERAND_RE.findall(op.rest)
                if operands and operands[0] in smap:
                    elementwise_flops += m * _shape_count(smap[operands[0]])

            # collectives (count -start, skip -done)
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                coll[base] += m * _shape_bytes(op.out_sig)

            # bytes (control computations only; fusion = one op)
            if comp not in interior and op.opcode not in _BYTE_SKIP:
                b = _shape_bytes(op.out_sig)
                badj = _shape_bytes_bf16adj(op.out_sig)
                for operand in _OPERAND_RE.findall(op.rest.split(" calls=")[0]):
                    sig = smap.get(operand)
                    if sig:
                        b += _shape_bytes(sig)
                        badj += _shape_bytes_bf16adj(sig)
                bytes_accessed += m * b
                bytes_bf16adj += m * badj

    coll_total = sum(coll.values())
    out = {
        "flops": flops,
        "elementwise_flops": elementwise_flops,
        "bytes_accessed": bytes_accessed,
        "bytes_bf16adj": bytes_bf16adj,
        "collective_bytes": dict(coll, total=coll_total),
        "n_computations": len(comps),
    }
    if top_ops:
        flop_items.sort(key=lambda t: -t[0])
        out["top_flop_ops"] = flop_items[:top_ops]
    return out
