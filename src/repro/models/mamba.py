"""Mamba-1 selective SSM block (Jamba's recurrent component).

The selective scan ``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` is evaluated
chunk-parallel: time is split into chunks of ``_SCAN_CHUNK``; a serial
``lax.scan`` carries the state across chunks while *within* a chunk the
recurrence runs as a parallel ``associative_scan`` (Blelloch) over the
(decay, increment) pairs.  This is the TPU-idiomatic mapping of the CUDA
selective-scan kernel (DESIGN.md §3): O(log chunk) depth, and the
``(B, chunk, d_inner, d_state)`` working set stays VMEM/HBM-friendly instead
of materializing the full ``(B, S, d_inner, d_state)`` tensor (which would be
~17 GB for Jamba at S=4096).

Decode keeps the constant-size state ``(B, d_inner, d_state)`` → long_500k
eligible.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MambaConfig
from repro.models.layers import init_dense

_SCAN_CHUNK = 256


class MambaCache(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, d_inner) — rolling conv inputs
    ssm: jnp.ndarray    # (B, d_inner, d_state)  — recurrent state


def _dt_rank(d_model: int, cfg: MambaConfig) -> int:
    return cfg.dt_rank or -(-d_model // 16)


def init_mamba(key, d_model: int, cfg: MambaConfig) -> dict:
    d_in = cfg.expand * d_model
    r = _dt_rank(d_model, cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_dense(ks[0], (d_model, 2 * d_in)),
        "conv_w": init_dense(ks[1], (cfg.d_conv, d_in)),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": init_dense(ks[2], (d_in, r + 2 * cfg.d_state)),
        "dt_proj": init_dense(ks[3], (r, d_in)),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        # A is stored as -exp(a_log) (negative-real); d_skip is a skip gain.
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_in, cfg.d_state)
        )).copy(),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_dense(ks[5], (d_in, d_model)),
    }


def init_mamba_cache(batch: int, d_model: int, cfg: MambaConfig,
                     dtype=jnp.float32) -> MambaCache:
    d_in = cfg.expand * d_model
    return MambaCache(
        jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
        jnp.zeros((batch, d_in, cfg.d_state), dtype),
    )


def slot_insert(cache: MambaCache, src: MambaCache,
                slots: jnp.ndarray) -> MambaCache:
    """Copy batch rows (rolling conv inputs + SSM state) into pool ``slots``.

    The SSM state is position-free — a row prefilled in a fresh batch-1 cache
    is exactly the state the request would have in any slot.
    """
    return MambaCache(cache.conv.at[slots].set(src.conv.astype(cache.conv.dtype)),
                      cache.ssm.at[slots].set(src.ssm.astype(cache.ssm.dtype)))


def slot_reset(cache: MambaCache, slots: jnp.ndarray) -> MambaCache:
    """Zero rows ``slots`` — bitwise identical to fresh ``init_mamba_cache``."""
    return MambaCache(cache.conv.at[slots].set(0), cache.ssm.at[slots].set(0))


# Paged serving (DESIGN.md §13): mamba state has no sequence axis — one
# constant-size row per slot — so there is nothing to page.  The recurrent
# families ride the *state* half of the split paged pool with the ordinary
# slot ops; they join prefix caching via state-row extraction instead.
paged_slot_insert = slot_insert
paged_slot_reset = slot_reset


def _selective_params(params: dict, x_conv: jnp.ndarray, d_state: int, r: int):
    """Project conv output → (Δ, B_t, C_t) selective parameters (f32)."""
    proj = jnp.einsum("...i,ie->...e", x_conv, params["x_proj"]).astype(jnp.float32)
    dt, b_sel, c_sel = jnp.split(proj, [r, r + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"])
    return dt, b_sel, c_sel


def mamba_block(
    params: dict,
    x: jnp.ndarray,           # (B, S, d_model)
    cfg: MambaConfig,
    *,
    cache: Optional[MambaCache] = None,
) -> Tuple[jnp.ndarray, Optional[MambaCache]]:
    b, s, d_model = x.shape
    d_in = cfg.expand * d_model
    r = _dt_rank(d_model, cfg)

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)  # (B, S, d_in) each

    # Depthwise causal conv over time.
    if cache is not None:
        conv_in = jnp.concatenate([cache.conv.astype(xs.dtype), xs], axis=1)
        new_conv = conv_in[:, -(cfg.d_conv - 1):, :].astype(cache.conv.dtype)
    else:
        conv_in = jnp.pad(xs, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
        new_conv = None
    x_conv = jax.nn.silu(
        sum(conv_in[:, i : i + s, :] * params["conv_w"][i]
            for i in range(cfg.d_conv))
        + params["conv_b"]).astype(x.dtype)

    a = -jnp.exp(params["a_log"])  # (d_in, N), negative real
    init_h = (cache.ssm.astype(jnp.float32) if cache is not None
              else jnp.zeros((b, d_in, cfg.d_state), jnp.float32))

    if cache is not None and s == 1:
        dt, b_sel, c_sel = _selective_params(params, x_conv, cfg.d_state, r)
        decay = jnp.exp(dt[:, 0, :, None] * a)
        inc = (dt[:, 0, :, None] * b_sel[:, 0, None, :]
               * x_conv.astype(jnp.float32)[:, 0, :, None])
        h = init_h * decay + inc
        new_ssm = h
        y = jnp.einsum("bin,bn->bi", h, c_sel[:, 0])[:, None, :]
    else:
        chunk = min(s, _SCAN_CHUNK)
        pad = (-s) % chunk
        xc = jnp.pad(x_conv, ((0, 0), (0, pad), (0, 0)))
        n_chunks = xc.shape[1] // chunk
        # (n_chunks, B, chunk, d_in) — scan over the leading chunk axis.
        xc = xc.reshape(b, n_chunks, chunk, d_in).transpose(1, 0, 2, 3)
        # Padded positions must be state-identity: x=0 kills the increment
        # but dt=softplus(conv_b-path)>0 would still *decay* the carried
        # state once per pad step — corrupting the cache a bulk prefill
        # saves.  (Within a chunk, pad < chunk, so position 0 is real.)
        valid = (jnp.arange(n_chunks * chunk) < s).reshape(n_chunks, chunk)

        def chunk_step(h, scanned):
            x_chunk, v_chunk = scanned
            dt, b_sel, c_sel = _selective_params(params, x_chunk, cfg.d_state, r)
            decay = jnp.exp(dt[..., None] * a)                  # (B,c,d_in,N)
            inc = (dt[..., None] * b_sel[:, :, None, :]
                   * x_chunk.astype(jnp.float32)[..., None])
            m = v_chunk[None, :, None, None]
            decay = jnp.where(m, decay, 1.0)
            inc = jnp.where(m, inc, 0.0)
            inc = inc.at[:, 0].add(h * decay[:, 0])

            def combine(left, right):
                dl, il = left
                dr, ir = right
                return dl * dr, il * dr + ir

            _, states = jax.lax.associative_scan(combine, (decay, inc), axis=1)
            y_chunk = jnp.einsum("bsin,bsn->bsi", states, c_sel)
            return states[:, -1], y_chunk.astype(x.dtype)

        new_ssm, ys = jax.lax.scan(chunk_step, init_h, (xc, valid))
        y = ys.transpose(1, 0, 2, 3).reshape(b, n_chunks * chunk, d_in)[:, :s]
        y = y.astype(jnp.float32)

    y = y + x_conv.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])

    new_cache = (MambaCache(new_conv, new_ssm.astype(cache.ssm.dtype))
                 if cache is not None else None)
    return out, new_cache
