"""Shared building blocks: norms, RoPE, embeddings, dense FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    return cap * jnp.tanh(x / cap)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU FFN: down( silu(x·gate) ⊙ (x·up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Logits via the (possibly tied) output table: (..., d) → (..., V)."""
    return jnp.einsum("...d,vd->...v", x, table)


def init_dense(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(jnp.bfloat16)
