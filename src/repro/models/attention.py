"""Causal self-attention: GQA/MHA, sliding-window, softcap, RoPE, KV cache.

Two execution regimes:

* **train/prefill** — for short sequences a single masked einsum; for long
  sequences (> ``_CHUNK_THRESHOLD``) a *blockwise online-softmax* scan over KV
  chunks (flash-attention recurrence in pure JAX) so peak memory is
  O(Sq · chunk) instead of O(Sq · Sk).  This is what makes the 32k-prefill
  cells lower within HBM.
* **decode** — one query token against a KV cache laid out
  ``(B, S_max, n_kv, head_dim)``; sliding-window archs keep a rolled cache of
  size ``window`` (bounded memory ⇒ long_500k eligibility).

GQA is realized by reshaping queries to (kv_groups, q_per_kv) and broadcasting
K/V — no repeat-materialization.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import AttentionConfig
from repro.models.layers import apply_rope, init_dense, softcap
from repro.sharding.ctx import constrain, logical_axis_size

_CHUNK_THRESHOLD = 8192
_KV_CHUNK = 1024
_NEG_INF = -1e30


def init_attention(key, d_model: int, cfg: AttentionConfig) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, (d_model, cfg.n_heads * cfg.head_dim)),
        "wk": init_dense(kk, (d_model, cfg.n_kv_heads * cfg.head_dim)),
        "wv": init_dense(kv, (d_model, cfg.n_kv_heads * cfg.head_dim)),
        "wo": init_dense(ko, (cfg.n_heads * cfg.head_dim, d_model)),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S_max, n_kv, head_dim)
    v: jnp.ndarray  # (B, S_max, n_kv, head_dim)


def init_cache(batch: int, max_seq: int, cfg: AttentionConfig,
               dtype=jnp.bfloat16) -> KVCache:
    size = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def slot_insert(cache: KVCache, src: KVCache, slots: jnp.ndarray) -> KVCache:
    """Copy batch rows of ``src`` into rows ``slots`` of the pooled cache.

    ``src`` is a freshly prefilled cache (same ``max_seq``/ring size as the
    pool) holding one row per admitted request; the per-slot position
    counter the engine keeps equals the request's own token count, so a
    rolling SWA ring inserted this way stays phase-consistent.
    """
    return KVCache(cache.k.at[slots].set(src.k.astype(cache.k.dtype)),
                   cache.v.at[slots].set(src.v.astype(cache.v.dtype)))


def slot_reset(cache: KVCache, slots: jnp.ndarray) -> KVCache:
    """Zero rows ``slots`` — bitwise identical to a fresh ``init_cache`` row."""
    return KVCache(cache.k.at[slots].set(0), cache.v.at[slots].set(0))


# -- paged variants (DESIGN.md §13) ----------------------------------------
#
# The paged pool replaces the per-slot ``(B, size, …)`` rows with a shared
# ``(num_pages, page_size, …)`` arena addressed through a host-side page
# table.  Page 0 is reserved all-zero, so gathering an unmapped table entry
# reproduces a fresh ``init_cache`` row bitwise — the gathered view feeds
# the *same* compiled decode step as the contiguous engine.


def init_paged_cache(num_pages: int, page_size: int, cfg: AttentionConfig,
                     dtype=jnp.bfloat16) -> KVCache:
    shape = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def paged_view(cache: KVCache, pt: jnp.ndarray, size: int) -> KVCache:
    """Gather per-slot contiguous rows from the page arena.

    ``pt`` is the (B, npp_max) page table; this family reads its first
    ``ceil(size / page_size)`` entries.  Unmapped (0) entries gather the
    reserved zero page, so the result is byte-equal to a contiguous pool
    row at the same decode position.
    """
    ps = cache.k.shape[1]
    npp = -(-size // ps)

    def g(pages):
        v = pages[pt[:, :npp]]                       # (B, npp, ps, kv, dh)
        return v.reshape(pt.shape[0], npp * ps, *pages.shape[2:])[:, :size]

    return KVCache(g(cache.k), g(cache.v))


def paged_commit(cache: KVCache, view: KVCache, pt: jnp.ndarray,
                 wpos: jnp.ndarray) -> KVCache:
    """Scatter the one position decode wrote back into the arena.

    ``wpos`` (B,) is the ring-adjusted write index the decode step used
    (``pos % size`` for rolling SWA, ``pos`` otherwise) — computed by the
    dispatch layer, which knows this family's ring geometry.  Slots whose
    write page is unmapped (retired/inactive — masked decode reverted their
    update) scatter gathered zeros onto the zero page: a no-op.
    """
    ps = cache.k.shape[1]
    bi = jnp.arange(pt.shape[0])
    phys = pt[bi, wpos // ps]
    off = wpos % ps
    return KVCache(
        cache.k.at[phys, off].set(view.k[bi, wpos].astype(cache.k.dtype)),
        cache.v.at[phys, off].set(view.v[bi, wpos].astype(cache.v.dtype)))


def paged_insert(cache: KVCache, src: KVCache, pt_rows: jnp.ndarray) -> KVCache:
    """Scatter freshly prefilled rows into newly mapped pages.

    ``src`` is the same fresh contiguous cache ``slot_insert`` takes, one
    row per admitted request; ``pt_rows`` are those requests' page-table
    rows.  Rows past the prompt are still zero after prefill (ring rebuild
    included), so unmapped trailing entries scatter zeros onto page 0.
    """
    ps = cache.k.shape[1]
    size = src.k.shape[1]
    npp = -(-size // ps)

    def s(pages, rows):
        pad = npp * ps - size
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)) + ((0, 0),) * (rows.ndim - 2))
        rows = rows.reshape(rows.shape[0], npp, ps, *rows.shape[2:])
        return pages.at[pt_rows[:, :npp]].set(rows.astype(pages.dtype))

    return KVCache(s(cache.k, src.k), s(cache.v, src.v))


def _scores_mask(scores: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 window: Optional[int]) -> jnp.ndarray:
    """Apply causal (+ optional sliding-window) mask to (..., Sq, Sk) scores.

    Positions are either shared across the batch (``(Sq,)`` / ``(Sk,)``) or
    per-sequence (``(B, Sq)`` / ``(B, Sk)`` — continuous-batching decode,
    where every cache slot carries its own position counter).
    """
    if q_pos.ndim == 2 or k_pos.ndim == 2:
        q2 = q_pos if q_pos.ndim == 2 else q_pos[None]
        k2 = k_pos if k_pos.ndim == 2 else k_pos[None]
        causal = q2[:, :, None] >= k2[:, None, :]
        if window is not None:
            causal &= (q2[:, :, None] - k2[:, None, :]) < window
        # scores: (B, n_kv, groups, Sq, Sk) — broadcast over the head axes.
        return jnp.where(causal[:, None, None], scores, _NEG_INF)
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        causal &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(causal, scores, _NEG_INF)


def _attend_full(q, k, v, q_pos, k_pos, cfg: AttentionConfig):
    """Masked full attention. q: (B,Sq,Hq,dh), k/v: (B,Sk,Hkv,dh)."""
    b, sq, hq, dh = q.shape
    groups = hq // cfg.n_kv_heads
    qg = q.reshape(b, sq, cfg.n_kv_heads, groups, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if cfg.logit_softcap:
        scores = softcap(scores, cfg.logit_softcap)
    scores = _scores_mask(scores, q_pos, k_pos, cfg.window)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, cfg: AttentionConfig,
                    chunk: int = _KV_CHUNK):
    """Online-softmax blockwise attention over KV chunks (flash recurrence)."""
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    groups = hq // cfg.n_kv_heads
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    n_chunks = k.shape[1] // chunk
    qg = (q.astype(jnp.float32) * dh ** -0.5).reshape(b, sq, cfg.n_kv_heads, groups, dh)

    kc = k.reshape(b, n_chunks, chunk, cfg.n_kv_heads, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, cfg.n_kv_heads, dh).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    def step(carry, inputs):
        m_prev, s_prev, o_prev = carry  # (b,kv,g,sq), same, (b,sq,kv,g,dh)
        kb, vb, pb = inputs
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb.astype(jnp.float32))
        if cfg.logit_softcap:
            scores = softcap(scores, cfg.logit_softcap)
        scores = _scores_mask(scores, q_pos, pb, cfg.window)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        s_new = s_prev * corr + jnp.sum(p, axis=-1)
        o_new = o_prev * corr.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bkgqs,bskd->bqkgd", p, vb.astype(jnp.float32)
        )
        return (m_new, s_new, o_new), None

    m0 = jnp.full((b, cfg.n_kv_heads, groups, sq), _NEG_INF, jnp.float32)
    s0 = jnp.zeros((b, cfg.n_kv_heads, groups, sq), jnp.float32)
    o0 = jnp.zeros((b, sq, cfg.n_kv_heads, groups, dh), jnp.float32)
    (m, s, o), _ = jax.lax.scan(step, (m0, s0, o0), (kc, vc, pc))
    out = o / jnp.maximum(s, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def _attend_banded(q, k, v, q_pos, k_pos, cfg: AttentionConfig,
                   chunk: int = _KV_CHUNK):
    """Sliding-window attention with banded blocking (§Perf iteration 3).

    Scans over query chunks; each chunk attends only to its KV band
    ``[qc_start − W, qc_end)`` (static size W+chunk), so FLOPs are
    S·(W+chunk)·d per head instead of the full S² rectangle — 6.4× fewer
    for mixtral's W=4096 at S=32k.  Correctness rides on the causal+window
    mask; the band provably covers every in-window key.
    """
    b, s, hq, dh = q.shape
    w = cfg.window
    band = w + chunk
    pad_q = (-s) % chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q),
                        constant_values=jnp.iinfo(jnp.int32).max // 2)
    # Left-pad KV by W so every band slice is in range with static size.
    k = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    k_pos = jnp.pad(k_pos, (w, 0), constant_values=jnp.iinfo(jnp.int32).max)
    n_chunks = q.shape[1] // chunk
    qc = q.reshape(b, n_chunks, chunk, hq, dh).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(n_chunks, chunk)

    def step(_, inputs):
        i, qb, pb = inputs
        start = i * chunk            # == (qc_start − W) + W of padded KV
        kb = jax.lax.dynamic_slice(k, (0, start, 0, 0),
                                   (b, band, k.shape[2], dh))
        vb = jax.lax.dynamic_slice(v, (0, start, 0, 0),
                                   (b, band, v.shape[2], dh))
        kp = jax.lax.dynamic_slice(k_pos, (start,), (band,))
        return None, _attend_full(qb, kb, vb, pb, kp, cfg)

    _, out = jax.lax.scan(step, None,
                          (jnp.arange(n_chunks), qc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, hq, dh)
    return out[:, :s]


def attention(
    params: dict,
    x: jnp.ndarray,                       # (B, S, d_model)
    positions: jnp.ndarray,               # (S,)
    cfg: AttentionConfig,
    *,
    kv_source: Optional[jnp.ndarray] = None,   # encoder states for cross-attn
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jnp.ndarray] = None,   # scalar: #tokens already cached
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Full attention block. Returns (output, updated_cache)."""
    b, s, _ = x.shape
    src = kv_source if kv_source is not None else x
    # Query heads pinned to TP shards (head-parallel attention); KV heads
    # follow if divisible (constrain drops the axis otherwise — GQA with
    # n_kv < tp runs with replicated KV, the standard fallback).
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    q = constrain(q, "dp", None, "tp", None)
    k = jnp.einsum("bsd,de->bse", src, params["wk"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    k = constrain(k, "dp", None, "tp", None)
    v = jnp.einsum("bsd,de->bse", src, params["wv"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = constrain(v, "dp", None, "tp", None)

    if kv_source is not None:
        # Cross-attention: no positions, no mask, no cache.
        scale = cfg.head_dim ** -0.5
        groups = cfg.n_heads // cfg.n_kv_heads
        qg = (q.astype(jnp.float32) * scale).reshape(
            b, s, cfg.n_kv_heads, groups, cfg.head_dim)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
        out = out.reshape(b, s, cfg.n_heads * cfg.head_dim).astype(x.dtype)
        return jnp.einsum("bse,ed->bsd", out, params["wo"]), None

    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if (cache is not None and s > 1 and cfg.window
            and cfg.window <= cache.k.shape[1]):
        # Bulk prefill into a rolling SWA cache.  A single dynamic_update_slice
        # can neither wrap around the ring nor exceed its length, and early
        # query tokens must attend to keys that later tokens will overwrite —
        # so attend over (old ring ∪ new tokens), then rebuild the ring with
        # the last `size` absolute positions via a gather.
        size = cache.k.shape[1]
        j = jnp.arange(size)
        # Absolute position held by slot j before the write: the largest
        # t ≡ j (mod size) with t < cache_pos (negative ⇒ never written).
        t_old = cache_pos - 1 - ((cache_pos - 1 - j) % size)
        k_pos = jnp.concatenate(
            [jnp.where(t_old >= 0, t_old, jnp.iinfo(jnp.int32).max),
             positions])
        k_cat = jnp.concatenate([cache.k.astype(k.dtype), k], axis=1)
        v_cat = jnp.concatenate([cache.v.astype(v.dtype), v], axis=1)
        # Long prompts: online-softmax over KV chunks — never materialize
        # the (Sq, size+Sq) score rectangle (same thresholds as cacheless).
        attend = (_attend_chunked if s > min(_CHUNK_THRESHOLD,
                                             cfg.window + _KV_CHUNK)
                  else _attend_full)
        out = attend(q, k_cat, v_cat, positions, k_pos, cfg)
        # After the write, slot j holds the largest t ≡ j (mod size) with
        # t < cache_pos + s; keep the old value where that t predates the
        # new tokens.
        t_new = cache_pos + s - 1 - ((cache_pos + s - 1 - j) % size)
        rel = jnp.clip(t_new - cache_pos, 0, s - 1)
        is_new = (t_new >= cache_pos)[None, :, None, None]
        new_cache = KVCache(
            jnp.where(is_new, jnp.take(k, rel, axis=1).astype(cache.k.dtype),
                      cache.k),
            jnp.where(is_new, jnp.take(v, rel, axis=1).astype(cache.v.dtype),
                      cache.v))
        out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
        out = constrain(out, "dp", None, "tp")
        return jnp.einsum("bse,ed->bsd", out, params["wo"]), new_cache
    if cache is not None and jnp.ndim(cache_pos) == 1:
        # Per-slot decode (continuous-batching engine): every sequence owns
        # one cache row and its own position counter, so the write index and
        # the key positions are per-batch.  Single-token steps only — bulk
        # prefill of a new request runs with a scalar cache_pos into a fresh
        # cache and is copied in via ``slot_insert``.
        if s != 1:
            raise NotImplementedError(
                "per-slot cache_pos supports single-token decode only; "
                "prefill into a fresh cache and slot_insert it instead")
        size = cache.k.shape[1]
        ring = bool(cfg.window) and cfg.window <= size
        slot = cache_pos % size if ring else cache_pos      # (B,)
        bi = jnp.arange(b)
        ck = cache.k.at[bi, slot].set(k[:, 0].astype(cache.k.dtype))
        cv = cache.v.at[bi, slot].set(v[:, 0].astype(cache.v.dtype))
        new_cache = KVCache(ck, cv)
        i = jnp.arange(size)[None, :]
        if ring:
            # Ring buffer: same pointer arithmetic as the scalar path, per row.
            base = (cache_pos - slot)[:, None]
            k_pos = jnp.where(i <= slot[:, None], i + base, i + base - size)
            k_pos = jnp.where(k_pos >= 0, k_pos, jnp.iinfo(jnp.int32).max)
        else:
            k_pos = jnp.where(i < cache_pos[:, None] + 1, i,
                              jnp.iinfo(jnp.int32).max)
        out = _attend_full(q, ck, cv, positions, k_pos, cfg)
    elif cache is not None:
        # Decode: append the s new tokens into the (possibly rolling) cache.
        size = cache.k.shape[1]
        if cfg.window and cfg.window <= size:
            slot = cache_pos % size  # rolling ring buffer for SWA
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, slot, 0, 0))
        new_cache = KVCache(ck, cv)
        k_all, v_all = ck, cv
        if cfg.window and cfg.window <= size:
            # Ring buffer: absolute position of slot i is recovered from the
            # write pointer; stale slots are masked by the causal check.
            k_pos = jnp.where(
                jnp.arange(size) <= slot,
                jnp.arange(size) + (cache_pos - slot),
                jnp.arange(size) + (cache_pos - slot) - size,
            )
            k_pos = jnp.where(k_pos >= 0, k_pos, jnp.iinfo(jnp.int32).max)
        else:
            k_pos = jnp.arange(k_all.shape[1])
            k_pos = jnp.where(k_pos < cache_pos + s, k_pos,
                              jnp.iinfo(jnp.int32).max)
        # Decode (s=1) attends densely; a bulk prefill over a long prompt
        # switches to the online-softmax chunked path (cacheless threshold).
        attend = _attend_chunked if s > _CHUNK_THRESHOLD else _attend_full
        out = attend(q, k_all, v_all, positions, k_pos, cfg)
    else:
        k_pos = positions
        # Train/prefill: expand GQA KV to full heads ONLY when the KV head
        # count can't shard over TP (n_kv % tp != 0) — expansion makes
        # attention cleanly head-parallel at the cost of transient
        # (rematerialized) KV; when KV heads divide TP they shard directly.
        # Decode always keeps grouped GQA (the cache dominates memory).
        groups = cfg.n_heads // cfg.n_kv_heads
        if groups > 1 and cfg.n_kv_heads % max(logical_axis_size("tp"), 1):
            k = jnp.repeat(k, groups, axis=2)
            v = jnp.repeat(v, groups, axis=2)
            k = constrain(k, "dp", None, "tp", None)
            v = constrain(v, "dp", None, "tp", None)
            cfg_full = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads)
        else:
            cfg_full = cfg
        if cfg.window is not None and s > cfg.window + _KV_CHUNK:
            out = _attend_banded(q, k, v, positions, k_pos, cfg_full)
        elif s > _CHUNK_THRESHOLD:
            out = _attend_chunked(q, k, v, positions, k_pos, cfg_full)
        else:
            out = _attend_full(q, k, v, positions, k_pos, cfg_full)

    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = constrain(out, "dp", None, "tp")
    return jnp.einsum("bse,ed->bsd", out, params["wo"]), new_cache
