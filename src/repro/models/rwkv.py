"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The WKV-6 recurrence per head (state ``S ∈ R^{dk×dv}``)::

    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

with the Finch signature feature: the decay ``w_t = exp(−exp(w0 + LoRA(x_t)))``
is *data-dependent* per channel per step.

TPU mapping (DESIGN.md §3): the serial recurrence is rewritten in the
standard *chunked linear-attention* form — within a chunk of ``_CHUNK``
tokens all terms become dense matmuls against cumulative decay products
(MXU-friendly), and a ``lax.scan`` carries the (B, H, dk, dv) state across
chunks.  Cumulative decays are applied in log space in f32 for stability.

Decode carries (prev-token vectors, state) — constant memory ⇒ long_500k.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense

_CHUNK = 32
_HEAD_DIM = 64
_DECAY_LORA = 64


class RWKVCache(NamedTuple):
    tm_prev: jnp.ndarray  # (B, d) last token entering time-mix
    cm_prev: jnp.ndarray  # (B, d) last token entering channel-mix
    state: jnp.ndarray    # (B, H, dk, dv) WKV state


def init_rwkv(key, d_model: int, d_ff: int) -> dict:
    h = d_model // _HEAD_DIM
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d_model), jnp.float32),  # r,k,v,w,g shift mix
        "w_r": init_dense(ks[0], (d_model, d_model)),
        "w_k": init_dense(ks[1], (d_model, d_model)),
        "w_v": init_dense(ks[2], (d_model, d_model)),
        "w_g": init_dense(ks[3], (d_model, d_model)),
        "w_o": init_dense(ks[4], (d_model, d_model)),
        "w0": -6.0 * jnp.ones((d_model,), jnp.float32),
        "w_lora_a": init_dense(ks[5], (d_model, _DECAY_LORA)),
        "w_lora_b": (jax.random.normal(ks[6], (_DECAY_LORA, d_model)) * 0.01
                     ).astype(jnp.bfloat16),
        "u_bonus": jnp.zeros((h, _HEAD_DIM), jnp.float32),
        "ln_x": jnp.zeros((d_model,), jnp.float32),
        # channel-mix
        "mu_cm": 0.5 * jnp.ones((2, d_model), jnp.float32),
        "cm_k": init_dense(ks[7], (d_model, d_ff)),
        "cm_v": init_dense(ks[8], (d_ff, d_model)),
        "cm_r": init_dense(ks[9], (d_model, d_model)),
    }


def init_rwkv_cache(batch: int, d_model: int, dtype=jnp.float32) -> RWKVCache:
    h = d_model // _HEAD_DIM
    return RWKVCache(
        jnp.zeros((batch, d_model), dtype),
        jnp.zeros((batch, d_model), dtype),
        jnp.zeros((batch, h, _HEAD_DIM, _HEAD_DIM), dtype),
    )


def slot_insert(cache: RWKVCache, src: RWKVCache,
                slots: jnp.ndarray) -> RWKVCache:
    """Copy batch rows (prev-token vectors + WKV state) into pool ``slots``."""
    return RWKVCache(
        cache.tm_prev.at[slots].set(src.tm_prev.astype(cache.tm_prev.dtype)),
        cache.cm_prev.at[slots].set(src.cm_prev.astype(cache.cm_prev.dtype)),
        cache.state.at[slots].set(src.state.astype(cache.state.dtype)))


def slot_reset(cache: RWKVCache, slots: jnp.ndarray) -> RWKVCache:
    """Zero rows ``slots`` — bitwise identical to fresh ``init_rwkv_cache``."""
    return RWKVCache(cache.tm_prev.at[slots].set(0),
                     cache.cm_prev.at[slots].set(0),
                     cache.state.at[slots].set(0))


# Paged serving (DESIGN.md §13): RWKV state is per-slot constant-size (no
# sequence axis), so it is never paged — it stays in the *state* half of
# the split paged pool under the ordinary slot ops and joins prefix caching
# through state-row extraction.
paged_slot_insert = slot_insert
paged_slot_reset = slot_reset


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Shift sequence right by one; position 0 sees ``prev`` (or zeros)."""
    first = (prev[:, None, :] if prev is not None
             else jnp.zeros_like(x[:, :1]))
    return jnp.concatenate([first.astype(x.dtype), x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, logw, u, state0):
    """Chunked WKV-6. r,k,v: (B,S,H,dk); logw: (B,S,H,dk) (≤0); u: (H,dk).

    Returns y: (B,S,H,dv), final state (B,H,dk,dv).
    """
    b, s, h, dk = r.shape
    chunk = min(s, _CHUNK)
    pad = (-s) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = r.shape[1] // chunk
    resh = lambda t: t.reshape(b, n_chunks, chunk, h, dk).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(logw)

    def step(state, inp):
        rb, kb, vb, lwb = (t.astype(jnp.float32) for t in inp)  # (B,c,H,dk)
        # Cumulative log-decay INCLUSIVE of step t: L_t = Σ_{s≤t} logw_s.
        lcum = jnp.cumsum(lwb, axis=1)
        l_prev = lcum - lwb                      # exclusive: Σ_{s<t}
        l_total = lcum[:, -1]                    # (B,H,dk)

        r_dec = rb * jnp.exp(l_prev)             # r̃_t = r_t ⊙ W_{t-1}
        k_inc = kb * jnp.exp(l_total[:, None] - lcum)  # k̃_s = k_s ⊙ W_c/W_s

        # Inter-chunk: y_inter_t = r̃_t · S_in.
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, state)

        # Intra-chunk (strictly past): scores_{t,s} = r_t·W_{t-1}/W_s·k_s.
        k_rel = kb * jnp.exp(-lcum)              # k_s / W_s
        scores = jnp.einsum("bchk,bshk->bhcs", r_dec, k_rel)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhcs,bshv->bchv", scores, vb)

        # Diagonal bonus term: r_t · diag(u) k_tᵀ v_t.
        bonus = jnp.einsum("bchk,hk,bchk->bch", rb, u, kb)
        y_diag = bonus[..., None] * vb

        # State update: S_out = diag(W_c) S_in + Σ_s diag(W_c/W_s) k_sᵀ v_s.
        s_new = (jnp.exp(l_total)[..., None] * state
                 + jnp.einsum("bshk,bshv->bhkv", k_inc, vb))
        return s_new, y_inter + y_intra + y_diag

    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, dk)[:, :s]
    return y, state


def rwkv_time_mix(
    params: dict,
    x: jnp.ndarray,           # (B, S, d) — pre-normed input
    *,
    prev: Optional[jnp.ndarray] = None,       # (B, d) last token (decode)
    state0: Optional[jnp.ndarray] = None,     # (B, H, dk, dv)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """WKV-6 time-mix. Returns (delta, last_token, new_state)."""
    b, s, d = x.shape
    h = d // _HEAD_DIM

    shifted = _token_shift(x, prev)
    mu = params["mu"][:, None, None, :]  # (5,1,1,d)
    mix = lambda i: x * mu[i] + shifted * (1.0 - mu[i])
    xr, xk, xv, xw, xg = (mix(i).astype(x.dtype) for i in range(5))

    to_heads = lambda t: t.reshape(b, s, h, _HEAD_DIM)
    r = to_heads(jnp.einsum("bsd,de->bse", xr, params["w_r"]))
    k = to_heads(jnp.einsum("bsd,de->bse", xk, params["w_k"]))
    v = to_heads(jnp.einsum("bsd,de->bse", xv, params["w_v"]))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"]))

    # Finch data-dependent decay: logw = −exp(w0 + LoRA(x_w)) ∈ (−∞, 0).
    lora = jnp.einsum("bsd,dr->bsr", xw, params["w_lora_a"])
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(lora), params["w_lora_b"])
    logw = -jnp.exp(params["w0"] + lora.astype(jnp.float32))  # (B,S,d)
    logw = to_heads(logw)

    if state0 is None:
        state0 = jnp.zeros((b, h, _HEAD_DIM, _HEAD_DIM), jnp.float32)
    y, state = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw, params["u_bonus"],
                            state0)
    y = y.reshape(b, s, d)
    # GroupNorm over heads (ln_x), then gate and project.
    yh = y.reshape(b, s, h, _HEAD_DIM)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, s, d) * (1.0 + params["ln_x"])).astype(x.dtype)
    tm_out = jnp.einsum("bse,ed->bsd", y * g, params["w_o"])
    return tm_out, x[:, -1], state


def rwkv_channel_mix(
    params: dict,
    x: jnp.ndarray,           # (B, S, d) — pre-normed input
    *,
    prev: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 channel-mix. Returns (delta, last_token)."""
    shifted = _token_shift(x, prev)
    mu_cm = params["mu_cm"][:, None, None, :]
    xk = (x * mu_cm[0] + shifted * (1 - mu_cm[0])).astype(x.dtype)
    xr = (x * mu_cm[1] + shifted * (1 - mu_cm[1])).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["cm_k"])))
    cm = jnp.einsum("bsf,fd->bsd", kk, params["cm_v"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["cm_r"]))
    return rr * cm, x[:, -1]
