"""Mixture-of-Experts FFN: top-k router + grouped capacity-based dispatch.

TPU-idiomatic dense-dispatch (Shazeer/Switch style): tokens are routed via
one-hot dispatch/combine tensors so the expert computation is one batched
einsum with the expert axis shardable over the ``model`` mesh axis (expert
parallelism).  Tokens compete for capacity *within their own sequence*
(group = batch row), which keeps the dispatch tensor at
``(B, S, E, C)`` with ``E·C ≈ capacity_factor·k·S`` — a ~few-percent FLOP
overhead relative to the expert FFN itself (see EXPERIMENTS.md §Roofline for
the measured ratio) and no cross-sequence routing traffic.

Supports shared experts (DeepSeek-V3: 1 shared + 256 routed, top-8), f32
router, and a Switch-style load-balancing auxiliary loss.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import init_dense
from repro.sharding.ctx import constrain, logical_axis_size


def init_moe(key, d_model: int, cfg: MoEConfig) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    e = cfg.n_experts
    f = cfg.d_ff_expert
    keys = jax.random.split(ke, 3)
    params = {
        "router": (jax.random.normal(kr, (d_model, e), dtype=jnp.float32)
                   * (d_model ** -0.5)),
        "w_gate": init_dense(keys[0], (e, d_model, f), scale=d_model ** -0.5),
        "w_up": init_dense(keys[1], (e, d_model, f), scale=d_model ** -0.5),
        "w_down": init_dense(keys[2], (e, f, d_model), scale=f ** -0.5),
    }
    if cfg.n_shared_experts:
        sk = jax.random.split(ks, 3)
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        params["shared"] = {
            "w_gate": init_dense(sk[0], (d_model, fs)),
            "w_up": init_dense(sk[1], (d_model, fs)),
            "w_down": init_dense(sk[2], (fs, d_model)),
        }
    return params


def _topk_mask(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """(..., E) → boolean mask of the per-token top-k experts."""
    thresh = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= thresh


def moe_ffn(params: dict, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the MoE FFN. x: (B, S, d) → (out, aux_loss)."""
    b0, s0, d = x.shape
    # Under sequence parallelism the residual stream arrives seq-sharded;
    # routing needs whole groups, so gather once here (the Megatron-SP
    # layer-entry AG) rather than letting the partitioner reshard every
    # dispatch einsum (observed as an all-to-all storm, §Perf iter 6).
    x = constrain(x, "dp", None, None)
    # Routing groups: fold sequence chunks of `group_size` into the batch
    # axis so dispatch/combine cost is linear in S (E·C ≈ cf·k·g per group).
    gsz = min(s0, cfg.group_size)
    pad = (-s0) % gsz
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    n_groups = x.shape[1] // gsz
    x = x.reshape(b0 * n_groups, gsz, d)

    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = max(1, int(cfg.capacity_factor * s * k / e))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    mask = _topk_mask(logits, k)  # (B, S, E), k per token
    gates = jnp.where(mask, probs, 0.0)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Position of each token within its expert's per-sequence buffer.
    pos_in_expert = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1  # (B, S, E)
    in_capacity = mask & (pos_in_expert < capacity)
    pos_clipped = jnp.where(in_capacity, pos_in_expert, 0)

    # dispatch[b, s, e, c] — one-hot over capacity slots.
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (b, s, e, capacity), 3)
    dispatch = (in_capacity[..., None] & (iota_c == pos_clipped[..., None])
                ).astype(x.dtype)
    combine = dispatch * gates.astype(x.dtype)[..., None]

    # Expert compute.  EP when the expert count divides the TP axis, else
    # TP over the expert-FFN width (mixtral: E=8 < 16 → f-sharding), matching
    # the weight-spec fallback in sharding/rules.py.
    ep = e % max(logical_axis_size("tp"), 1) == 0
    xe = jnp.einsum("bsd,bsec->becd", x, dispatch)      # (B, E, C, d)
    # EP: expert axis sharded.  f-TP fallback (E < tp): shard the d axis of
    # the dispatched tokens so the dispatch/combine einsums don't replicate
    # across model shards (§Perf iter 4 — 16× dispatch work otherwise).
    xe = constrain(xe, "dp", "tp" if ep else None, None,
                   None if ep else "tp")
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, params["w_gate"]))
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"])
    spec_f = ("dp", "tp", None, None) if ep else ("dp", None, None, "tp")
    g = constrain(g, *spec_f)
    u = constrain(u, *spec_f)
    ye = jnp.einsum("becf,efd->becd", g * u, params["w_down"])
    ye = constrain(ye, "dp", "tp" if ep else None, None,
                   None if ep else "tp")
    out = jnp.einsum("becd,bsec->bsd", ye, combine)
    # f-TP mode: keep the combine output d-sharded (one AG at the residual
    # boundary beats 16× replicated combine FLOPs).
    out = constrain(out, "dp", None, None if ep else "tp")

    if cfg.n_shared_experts:
        sh = params["shared"]
        gs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, sh["w_up"])
        gs = constrain(gs, "dp", None, "tp")
        out = out + jnp.einsum("bsf,fd->bsd", gs, sh["w_down"])

    # Load-balancing auxiliary loss (Switch-style): E · Σ_e f_e · p_e / k.
    frac_tokens = jnp.mean(mask.astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) / k

    out = out.reshape(b0, n_groups * gsz, d)[:, :s0]
    return out, aux
