"""Per-layer block dispatch: init + forward for every block kind.

A *layer* = mixer (attention / MLA / mamba / rwkv time-mix / cross-attn)
followed by an FFN (dense SwiGLU or MoE), pre-norm residual style.  The
layer's parameter tree and cache tree depend only on its ``kind`` and its
position-in-pattern (which fixes the FFN kind), so layers at the same
pattern position can be stacked and scanned over periods (model.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import rwkv as rwkv_mod
from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm
from repro.models.moe import init_moe, moe_ffn
from repro.sharding.ctx import constrain

ATTN_KINDS = ("attn", "attn_local", "attn_global", "xattn")


def _attn_cfg(cfg: ModelConfig, kind: str):
    a = cfg.attention
    if kind == "attn_global":
        return dataclasses.replace(a, window=None)
    if kind == "attn_local":
        assert a.window is not None, "attn_local requires attention.window"
        return a
    if kind == "xattn":
        return dataclasses.replace(a, window=None, use_rope=False)
    return a


def init_layer(key, cfg: ModelConfig, kind: str, ffn: str) -> dict:
    """Parameters for one layer of the given kind + ffn ('dense'|'moe'|'none')."""
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    params: dict = {"norm1": jnp.zeros((d,), jnp.float32)}
    if kind in ATTN_KINDS:
        params["mixer"] = attn_mod.init_attention(k1, d, _attn_cfg(cfg, kind))
    elif kind == "mla":
        params["mixer"] = mla_mod.init_mla(k1, d, cfg.mla)
    elif kind == "mamba":
        params["mixer"] = mamba_mod.init_mamba(k1, d, cfg.mamba)
    elif kind == "rwkv":
        params["mixer"] = rwkv_mod.init_rwkv(k1, d, cfg.d_ff)
    else:
        raise ValueError(f"unknown block kind {kind}")

    if kind != "rwkv":  # rwkv's channel-mix is its FFN (inside mixer params)
        params["norm2"] = jnp.zeros((d,), jnp.float32)
        if ffn == "moe":
            params["ffn"] = init_moe(k2, d, cfg.moe)
        else:
            kg, ku, kd = jax.random.split(k3, 3)
            params["ffn"] = {
                "w_gate": init_dense(kg, (d, cfg.d_ff)),
                "w_up": init_dense(ku, (d, cfg.d_ff)),
                "w_down": init_dense(kd, (cfg.d_ff, d)),
            }
    else:
        params["norm2"] = jnp.zeros((d,), jnp.float32)
    return params


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    """Decode cache pytree for one layer (None for cacheless kinds)."""
    if kind == "xattn":
        return None  # encoder K/V recomputed from the (small) encoder states
    if kind in ATTN_KINDS:
        return attn_mod.init_cache(batch, max_seq, _attn_cfg(cfg, kind))
    if kind == "mla":
        return mla_mod.init_mla_cache(batch, max_seq, cfg.mla)
    if kind == "mamba":
        return mamba_mod.init_mamba_cache(batch, cfg.d_model, cfg.mamba)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(batch, cfg.d_model)
    raise ValueError(kind)


def slot_insert_cache(kind: str, cache, src, slots):
    """Slot-wise insert for one layer's cache (dispatch on block kind)."""
    if cache is None:
        return None
    if kind in ATTN_KINDS:
        return attn_mod.slot_insert(cache, src, slots)
    if kind == "mla":
        return mla_mod.slot_insert(cache, src, slots)
    if kind == "mamba":
        return mamba_mod.slot_insert(cache, src, slots)
    if kind == "rwkv":
        return rwkv_mod.slot_insert(cache, src, slots)
    raise ValueError(kind)


def cache_needs_snapshot(cfg: ModelConfig, kind: str, cache) -> bool:
    """True when a speculative rollback must keep per-step history of this
    layer's cache (DESIGN.md §11).

    Recurrent state (mamba / rwkv) has no positional axis to rewind.  A
    rolling SWA ring is positional but *destructive*: a draft step's write at
    ``pos % size`` overwrites the previous lap's entry, which is still inside
    the attention window after a rollback — so the ring needs snapshots too.
    Plain KV / MLA caches are append-only and masked by position
    (``k_pos < cache_pos + 1``), so rewinding the position counter alone
    makes stale draft writes invisible; they return False.
    """
    if cache is None:
        return False
    if kind in ("mamba", "rwkv"):
        return True
    if kind in ATTN_KINDS:
        a = _attn_cfg(cfg, kind)
        # Mirrors the decode-path ring test: size = min(max_seq, window).
        return bool(a.window) and a.window <= cache.k.shape[1]
    return False


def slot_reset_cache(kind: str, cache, slots):
    """Slot-wise reset for one layer's cache (dispatch on block kind)."""
    if cache is None:
        return None
    if kind in ATTN_KINDS:
        return attn_mod.slot_reset(cache, slots)
    if kind == "mla":
        return mla_mod.slot_reset(cache, slots)
    if kind == "mamba":
        return mamba_mod.slot_reset(cache, slots)
    if kind == "rwkv":
        return rwkv_mod.slot_reset(cache, slots)
    raise ValueError(kind)


# -- paged cache dispatch (DESIGN.md §13) ----------------------------------
#
# The paged pool splits per family: attention/MLA caches have a sequence
# axis and live as (num_pages, page_size, …) arenas addressed through a
# page table; mamba/RWKV state is constant-size per slot and stays in a
# plain (n_slots, …) *state* tree under the ordinary slot ops.  A layer
# contributes to exactly one of the two trees (None in the other), which is
# what lets ``model._map_layer_caches`` walk both with the same machinery.


def paged_geometry(cfg: ModelConfig, kind: str, max_seq: int):
    """Sequence-axis geometry of one layer's paged cache.

    Returns ``(size, ring)`` — the per-slot cache length and whether decode
    writes roll (``pos % size``) — or None for kinds with nothing to page
    (cacheless xattn, constant-size mamba/RWKV state).
    """
    if kind == "xattn" or kind in ("mamba", "rwkv"):
        return None
    if kind in ATTN_KINDS:
        a = _attn_cfg(cfg, kind)
        size = min(max_seq, a.window) if a.window else max_seq
        return size, bool(a.window) and a.window <= size
    if kind == "mla":
        return max_seq, False
    raise ValueError(kind)


def init_paged_layer_cache(cfg: ModelConfig, kind: str, num_pages: int,
                           page_size: int):
    """Page-arena leaf for one layer (None for unpaged kinds)."""
    if kind == "xattn" or kind in ("mamba", "rwkv"):
        return None
    if kind in ATTN_KINDS:
        return attn_mod.init_paged_cache(num_pages, page_size,
                                         _attn_cfg(cfg, kind))
    return mla_mod.init_paged_cache(num_pages, page_size, cfg.mla)


def init_paged_state_cache(cfg: ModelConfig, kind: str, n_slots: int):
    """Recurrent-state leaf for one layer (None for paged/cacheless kinds)."""
    if kind == "mamba":
        return mamba_mod.init_mamba_cache(n_slots, cfg.d_model, cfg.mamba)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(n_slots, cfg.d_model)
    return None


def _wpos(cfg: ModelConfig, kind: str, pos, max_seq: int):
    """Ring-adjusted per-slot write index (mirrors the decode-step branch)."""
    size, ring = paged_geometry(cfg, kind, max_seq)
    return pos % size if ring else pos


def paged_view_cache(cfg: ModelConfig, kind: str, cache, pt, max_seq: int):
    """Gather one layer's per-slot contiguous view from its page arena."""
    if cache is None:
        return None
    size, _ = paged_geometry(cfg, kind, max_seq)
    if kind in ATTN_KINDS:
        return attn_mod.paged_view(cache, pt, size)
    return mla_mod.paged_view(cache, pt, size)


def paged_commit_cache(cfg: ModelConfig, kind: str, cache, view, pt, pos,
                       max_seq: int):
    """Scatter the decode-written position of ``view`` back into the arena."""
    if cache is None:
        return None
    wpos = _wpos(cfg, kind, pos, max_seq)
    if kind in ATTN_KINDS:
        return attn_mod.paged_commit(cache, view, pt, wpos)
    return mla_mod.paged_commit(cache, view, pt, wpos)


def paged_insert_cache(kind: str, cache, src, pt_rows):
    """Scatter freshly prefilled rows into newly mapped pages."""
    if cache is None:
        return None
    if kind in ATTN_KINDS:
        return attn_mod.paged_insert(cache, src, pt_rows)
    return mla_mod.paged_insert(cache, src, pt_rows)


def paged_copy_pages(kind: str, cache, src_ids, dst_ids):
    """Copy whole pages ``src_ids → dst_ids`` (COW fork; (0,0) pads no-op)."""
    if cache is None:
        return None
    return type(cache)(*(leaf.at[dst_ids].set(leaf[src_ids])
                         for leaf in cache))


def apply_layer(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    ffn: str,
    *,
    encoder_states: Optional[jnp.ndarray] = None,
    cache: Any = None,
    cache_pos: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Apply one layer. Returns (x, new_cache, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    # Sequence parallelism (Korthikanti et al.): the residual stream — and
    # with it every remat-saved layer boundary — lives sequence-sharded over
    # TP; XLA inserts the AG before attention/FFN and the RS after.  Cuts
    # saved-activation memory by tp× (§Perf iter 6).  Decode (s=1) drops
    # the constraint automatically.  MoE layers opt out: grouped routing
    # over a seq-sharded stream degenerates into all-to-all storms
    # (measured 1.6e12 → 6.4e12 coll bytes on deepseek; §Perf iter 6b).
    seq = "tp" if ffn != "moe" else None
    x = constrain(x, "dp", seq, None)
    h = rms_norm(x, params["norm1"], eps)

    if kind in ("attn", "attn_local", "attn_global"):
        delta, new_cache = attn_mod.attention(
            params["mixer"], h, positions, _attn_cfg(cfg, kind),
            cache=cache, cache_pos=cache_pos)
    elif kind == "xattn":
        delta, new_cache = attn_mod.attention(
            params["mixer"], h, positions, _attn_cfg(cfg, kind),
            kv_source=encoder_states)
    elif kind == "mla":
        delta, new_cache = mla_mod.mla_attention(
            params["mixer"], h, positions, cfg.mla,
            cache=cache, cache_pos=cache_pos)
    elif kind == "mamba":
        delta, new_cache = mamba_mod.mamba_block(
            params["mixer"], h, cfg.mamba, cache=cache)
    elif kind == "rwkv":
        prev = cache.tm_prev if cache is not None else None
        st = cache.state if cache is not None else None
        delta, tm_last, new_state = rwkv_mod.rwkv_time_mix(
            params["mixer"], h, prev=prev, state0=st)
        x = x + delta
        h2 = rms_norm(x, params["norm2"], eps)
        cm_prev = cache.cm_prev if cache is not None else None
        delta2, cm_last = rwkv_mod.rwkv_channel_mix(
            params["mixer"], h2, prev=cm_prev)
        new_cache = None
        if cache is not None:
            new_cache = rwkv_mod.RWKVCache(
                tm_last.astype(cache.tm_prev.dtype),
                cm_last.astype(cache.cm_prev.dtype),
                new_state.astype(cache.state.dtype))
        return x + delta2, new_cache, aux
    else:
        raise ValueError(kind)

    x = x + constrain(delta, "dp", seq, None)
    h2 = rms_norm(x, params["norm2"], eps)
    if ffn == "moe":
        delta2, aux = moe_ffn(params["ffn"], h2, cfg.moe)
    else:
        # Megatron pattern: d_ff intermediate pinned to TP shards, so the
        # partitioner emits exactly one AR (after w_down), never a
        # contraction-sharded d_ff-wide AR.
        f = params["ffn"]
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h2, f["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", h2, f["w_up"])
        g = constrain(g, "dp", None, "tp")
        u = constrain(u, "dp", None, "tp")
        delta2 = jnp.einsum("bsf,fd->bsd", g * u, f["w_down"])
    return x + constrain(delta2, "dp", seq, None), new_cache, aux
