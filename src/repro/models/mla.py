"""Multi-head Latent Attention (DeepSeek-V2/V3).

K/V are compressed into a shared latent ``c_kv ∈ R^{kv_lora_rank}`` plus a
decoupled RoPE key ``k_rope ∈ R^{qk_rope_head_dim}``; queries go through a
low-rank bottleneck ``q_lora_rank``.  The decode cache stores only
``(c_kv, k_rope)`` per position — (512+64) floats for DeepSeek-V3 instead of
2·128·128 for vanilla MHA: a 57× KV-memory compression.  That compressed
cache is why the long_500k cell is runnable for deepseek-v3 (DESIGN.md §5).

Decode uses the standard MLA absorption trick: since
``k_nope = c_kv · W_uk`` and score = q_nopeᵀk_nope, we fold ``W_uk`` into the
query (``q̃ = W_ukᵀ q_nope``) and attend directly over the latent cache —
never materializing per-head K/V for past positions.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MLAConfig
from repro.models.layers import apply_rope, init_dense


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S_max, kv_lora_rank)
    k_rope: jnp.ndarray  # (B, S_max, qk_rope_head_dim)


def init_mla(key, d_model: int, cfg: MLAConfig) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "w_dq": init_dense(ks[0], (d_model, cfg.q_lora_rank)),
        "w_uq": init_dense(ks[1], (cfg.q_lora_rank, cfg.n_heads * cfg.qk_head_dim)),
        "w_dkv": init_dense(ks[2], (d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim)),
        "w_uk": init_dense(ks[3], (cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_head_dim)),
        "w_uv": init_dense(ks[4], (cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim)),
        "w_o": init_dense(ks[5], (cfg.n_heads * cfg.v_head_dim, d_model)),
    }


def init_mla_cache(batch: int, max_seq: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    )


def slot_insert(cache: MLACache, src: MLACache, slots: jnp.ndarray) -> MLACache:
    """Copy batch rows of a freshly prefilled latent cache into pool ``slots``."""
    return MLACache(
        cache.c_kv.at[slots].set(src.c_kv.astype(cache.c_kv.dtype)),
        cache.k_rope.at[slots].set(src.k_rope.astype(cache.k_rope.dtype)))


def slot_reset(cache: MLACache, slots: jnp.ndarray) -> MLACache:
    """Zero rows ``slots`` — bitwise identical to fresh ``init_mla_cache`` rows."""
    return MLACache(cache.c_kv.at[slots].set(0), cache.k_rope.at[slots].set(0))


# -- paged variants (DESIGN.md §13) ----------------------------------------
# Same arena/page-table scheme as attention.paged_*; the latent cache has no
# head axis, just (num_pages, page_size, rank) leaves.  MLA never rolls a
# ring, so the commit write index is always the raw position counter.


def init_paged_cache(num_pages: int, page_size: int, cfg: MLAConfig,
                     dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        jnp.zeros((num_pages, page_size, cfg.kv_lora_rank), dtype),
        jnp.zeros((num_pages, page_size, cfg.qk_rope_head_dim), dtype))


def paged_view(cache: MLACache, pt: jnp.ndarray, size: int) -> MLACache:
    """Gather per-slot contiguous latent rows from the page arena (unmapped
    table entries read the reserved zero page → fresh-cache bytes)."""
    ps = cache.c_kv.shape[1]
    npp = -(-size // ps)

    def g(pages):
        v = pages[pt[:, :npp]]                       # (B, npp, ps, r)
        return v.reshape(pt.shape[0], npp * ps, *pages.shape[2:])[:, :size]

    return MLACache(g(cache.c_kv), g(cache.k_rope))


def paged_commit(cache: MLACache, view: MLACache, pt: jnp.ndarray,
                 wpos: jnp.ndarray) -> MLACache:
    """Scatter the decode-written position back into the arena (``wpos`` is
    the per-slot position counter — MLA caches never ring)."""
    ps = cache.c_kv.shape[1]
    bi = jnp.arange(pt.shape[0])
    phys = pt[bi, wpos // ps]
    off = wpos % ps
    return MLACache(
        cache.c_kv.at[phys, off].set(
            view.c_kv[bi, wpos].astype(cache.c_kv.dtype)),
        cache.k_rope.at[phys, off].set(
            view.k_rope[bi, wpos].astype(cache.k_rope.dtype)))


def paged_insert(cache: MLACache, src: MLACache,
                 pt_rows: jnp.ndarray) -> MLACache:
    """Scatter freshly prefilled latent rows into newly mapped pages."""
    ps = cache.c_kv.shape[1]
    size = src.c_kv.shape[1]
    npp = -(-size // ps)

    def s(pages, rows):
        pad = npp * ps - size
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)) + ((0, 0),) * (rows.ndim - 2))
        rows = rows.reshape(rows.shape[0], npp, ps, *rows.shape[2:])
        return pages.at[pt_rows[:, :npp]].set(rows.astype(pages.dtype))

    return MLACache(s(cache.c_kv, src.c_kv), s(cache.k_rope, src.k_rope))


_NEG_INF = -1e30


def mla_attention(
    params: dict,
    x: jnp.ndarray,             # (B, S, d)
    positions: jnp.ndarray,     # (S,)
    cfg: MLAConfig,
    *,
    rope_theta: float = 10000.0,
    cache: Optional[MLACache] = None,
    cache_pos: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[MLACache]]:
    b, s, d = x.shape
    h = cfg.n_heads

    # Query path: low-rank down + up, split nope/rope parts.
    q = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
    q = jnp.einsum("bsr,re->bse", q, params["w_uq"]).reshape(
        b, s, h, cfg.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    # KV path: shared latent + decoupled rope key.
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :]

    if cache is None:
        # Prefill/train: materialize per-head K/V from the latent (absorption
        # only wins at decode) and reuse the blockwise online-softmax
        # attention so 32k-prefill memory stays O(S · chunk).
        from repro.models.attention import _attend_chunked, _attend_full, _CHUNK_THRESHOLD
        from repro.models.config import AttentionConfig

        k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]).reshape(
            b, s, h, cfg.qk_nope_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, cfg.qk_rope_head_dim))], axis=-1)
        v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]).reshape(
            b, s, h, cfg.v_head_dim)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # Pad V up to the QK head dim so the flash recurrence is square.
        pad_v = cfg.qk_head_dim - cfg.v_head_dim
        if pad_v > 0:
            v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad_v)))
        acfg = AttentionConfig(n_heads=h, n_kv_heads=h, head_dim=cfg.qk_head_dim,
                               use_rope=False)
        attend = _attend_chunked if s > _CHUNK_THRESHOLD else _attend_full
        o = attend(q_full, k_full, v, positions, positions, acfg)
        o = o[..., : cfg.v_head_dim].reshape(b, s, h * cfg.v_head_dim)
        return jnp.einsum("bse,ed->bsd", o, params["w_o"]), None

    new_cache = None
    if cache is not None and jnp.ndim(cache_pos) == 1:
        # Per-slot decode (continuous-batching engine): each sequence owns a
        # cache row with its own position counter; single-token steps only.
        if s != 1:
            raise NotImplementedError(
                "per-slot cache_pos supports single-token decode only; "
                "prefill into a fresh cache and slot_insert it instead")
        bi = jnp.arange(b)
        ck = cache.c_kv.at[bi, cache_pos].set(
            c_kv[:, 0].astype(cache.c_kv.dtype))
        cr = cache.k_rope.at[bi, cache_pos].set(
            k_rope[:, 0].astype(cache.k_rope.dtype))
        new_cache = MLACache(ck, cr)
        c_all, r_all = ck, cr
        k_pos = jnp.arange(c_all.shape[1])[None, :]          # (1, T)
        k_pos = jnp.where(k_pos < cache_pos[:, None] + 1, k_pos,
                          jnp.iinfo(jnp.int32).max)          # (B, T)
    elif cache is not None:
        ck = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, cache_pos, 0))
        new_cache = MLACache(ck, cr)
        c_all, r_all = ck, cr
        k_pos = jnp.arange(c_all.shape[1])
        k_pos = jnp.where(k_pos < cache_pos + s, k_pos, jnp.iinfo(jnp.int32).max)
    else:
        c_all, r_all = c_kv, k_rope
        k_pos = positions

    # Absorption: fold W_uk into the query → attend over the latent directly.
    w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))     # (B,S,H,kv_rank)
    scale = cfg.qk_head_dim ** -0.5
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_all.astype(jnp.float32))
        + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                     r_all.astype(jnp.float32))
    ) * scale
    if positions.ndim == 2 or k_pos.ndim == 2:
        # Per-sequence positions: (B, S) vs (B, T) → (B, 1, S, T) mask.
        p2 = positions if positions.ndim == 2 else positions[None]
        k2 = k_pos if k_pos.ndim == 2 else k_pos[None]
        mask = (p2[:, :, None] >= k2[:, None, :])[:, None]
    else:
        mask = (positions[:, None] >= k_pos[None, :])[None, None]
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)

    # Attend over the latent, then up-project per head (absorbed W_uv).
    o_lat = jnp.einsum("bhst,btr->bshr", probs, c_all.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    o = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, s, h * cfg.v_head_dim).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["w_o"]), new_cache
