"""Model configuration for the assigned architecture zoo.

A single flexible decoder backbone covers all ten architectures via a
*periodic block pattern*: the layer stack is ``pattern × n_periods`` where
``pattern`` is a short tuple of block kinds.  The forward pass scans over
periods (compile size O(|pattern|), not O(n_layers)) — e.g.

  stablelm-12b:  pattern=("attn",) × 40 periods
  gemma2-27b:    pattern=("attn_local", "attn_global") × 23 periods
  jamba-52b:     pattern=("mamba","moe_marker"… ) — see configs/jamba_v01_52b.py
  rwkv6:         pattern=("rwkv",) × 24

Block kinds:
  attn          — causal self-attention (GQA/MHA, optional window/softcap)
  attn_local    — sliding-window attention (window = cfg.attention.window)
  attn_global   — full-context attention
  mamba         — Mamba-1 selective SSM block
  rwkv          — RWKV-6 (Finch) time-mix + channel-mix block
  xattn         — cross-attention to encoder states (VLM)

Each block kind is followed by its FFN (dense or MoE, per-layer via
``moe_every``).  Modality frontends (vision patches / EnCodec frames) are
STUBS per the brief: inputs arrive as precomputed embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: Optional[int] = None          # sliding-window size (SWA); None=full
    logit_softcap: Optional[float] = None  # gemma2-style attn-score softcap
    rope_theta: float = 10000.0
    use_rope: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # Routing-group size (tokens compete for capacity within a group).
    # Bounds the dense dispatch/combine einsums at 2·cf·k·g·d FLOPs/token —
    # without grouping they are quadratic in sequence length (§Perf iter 2).
    group_size: int = 2048


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class SketchHeadConfig:
    """Representer-Sketch LM head (the paper's technique; DESIGN.md §4)."""
    n_rows: int = 64       # L
    n_buckets: int = 16    # R
    k: int = 2
    proj_dim: int = 64     # d' of the asymmetric transform
    bandwidth: float = 4.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[str, ...]                 # block kinds, one period
    attention: Optional[AttentionConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    moe_every: int = 0                       # every k-th layer uses MoE FFN (0=never,1=all)
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # VLM/audio frontend stubs: number of encoder tokens supplied per sample.
    n_encoder_tokens: int = 0
    # Representer-Sketch head (serve-time alternative to the dense head).
    sketch_head: Optional[SketchHeadConfig] = None
    # Long-context capability: True if decode memory is sub-linear in seq
    # (bounded window / recurrent state / compressed latent).
    subquadratic: bool = False
    # First N layers run unscanned with a dense FFN (DeepSeek-V3's 3 dense
    # prologue layers before the MoE stack).  Kind = pattern[0].
    n_dense_prologue: int = 0

    def __post_init__(self):
        assert (self.n_layers - self.n_dense_prologue) % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} minus prologue "
            f"{self.n_dense_prologue} not divisible by pattern length "
            f"{len(self.pattern)}"
        )

    @property
    def n_periods(self) -> int:
        return (self.n_layers - self.n_dense_prologue) // len(self.pattern)

    def layer_kind(self, layer_idx: int) -> str:
        if layer_idx < self.n_dense_prologue:
            return self.pattern[0]
        return self.pattern[(layer_idx - self.n_dense_prologue) % len(self.pattern)]

    def ffn_kind(self, layer_idx: int) -> str:
        """'moe' or 'dense' for the FFN following block ``layer_idx``."""
        if layer_idx < self.n_dense_prologue:
            return "dense"
        if self.moe is None or self.moe_every == 0:
            return "dense"
        if self.moe_every == 1:
            return "moe"
        return "moe" if (layer_idx % self.moe_every == self.moe_every - 1) else "dense"

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced copy for smoke tests (see configs/smoke.py)."""
        return dataclasses.replace(self, **overrides)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embedding + blocks + head)."""
    d = cfg.d_model
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # head
    for j in range(cfg.n_layers):
        kind = cfg.layer_kind(j)
        if kind in ("attn", "attn_local", "attn_global", "xattn"):
            a = cfg.attention
            total += d * a.n_heads * a.head_dim  # q
            total += 2 * d * a.n_kv_heads * a.head_dim  # k, v
            total += a.n_heads * a.head_dim * d  # o
        elif kind == "mla":
            m = cfg.mla
            total += d * m.q_lora_rank + m.q_lora_rank * m.n_heads * m.qk_head_dim
            total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            total += m.kv_lora_rank * m.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            total += m.n_heads * m.v_head_dim * d
        elif kind == "mamba":
            mb = cfg.mamba
            d_in = mb.expand * d
            dt_rank = mb.dt_rank or -(-d // 16)
            total += d * 2 * d_in               # in_proj
            total += d_in * mb.d_conv           # conv
            total += d_in * (dt_rank + 2 * mb.d_state)  # x_proj
            total += dt_rank * d_in + d_in      # dt_proj
            total += 2 * d_in * mb.d_state      # A (log) and D-ish terms
            total += d_in * d                   # out_proj
        elif kind == "rwkv":
            # time-mix: r,k,v,g,o projections + decay LoRA + mixing vectors;
            # channel-mix: k (d→ff), v (ff→d), r (d→d).
            total += 5 * d * d + 2 * 64 * d + 12 * d
            total += 2 * d * cfg.d_ff + d * d
        # FFN
        if kind != "rwkv":  # rwkv block includes its own channel mix
            if cfg.ffn_kind(j) == "moe":
                mo = cfg.moe
                total += d * mo.n_experts  # router
                total += (mo.n_experts + mo.n_shared_experts) * 3 * d * mo.d_ff_expert
            else:
                total += 3 * d * cfg.d_ff  # gate, up, down (SwiGLU)
        total += 2 * d  # norms
    total += d  # final norm
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    if cfg.moe is None or cfg.moe_every == 0:
        return param_count(cfg)
    mo = cfg.moe
    full = param_count(cfg)
    n_moe_layers = sum(
        1 for j in range(cfg.n_layers) if cfg.ffn_kind(j) == "moe"
    )
    inactive = n_moe_layers * (mo.n_experts - mo.top_k) * 3 * cfg.d_model * mo.d_ff_expert
    return full - inactive
