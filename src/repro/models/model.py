"""Decoder backbone: embedding → (prologue + scanned periods) → head.

Compile-size discipline: the layer stack is executed as ``lax.scan`` over
*periods* of the block pattern, so the lowered HLO contains one copy of each
pattern position regardless of depth (61-layer DeepSeek lowers as 1 MLA body
+ 3 prologue layers).  Parameters of the scanned layers carry a leading
``n_periods`` axis; decode caches are stacked the same way and threaded
through the scan.

Train mode rematerializes each period body (``jax.checkpoint``) — the
standard memory/compute trade for long-sequence training.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import embed, init_dense, rms_norm, softcap, unembed
from repro.sharding.ctx import constrain


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kh, kp, ks = jax.random.split(key, 4)
    params: dict = {
        "embed": init_dense(ke, (cfg.vocab_size, cfg.d_model), scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_dense(kh, (cfg.vocab_size, cfg.d_model), scale=0.02)

    # Unscanned prologue layers.
    prologue = []
    for i in range(cfg.n_dense_prologue):
        kp, sub = jax.random.split(kp)
        prologue.append(blocks.init_layer(sub, cfg, cfg.pattern[0], "dense"))
    if prologue:
        params["prologue"] = prologue

    # Scanned periods: one stacked tree per pattern position.
    period_params = {}
    for j, kind in enumerate(cfg.pattern):
        ffn = cfg.ffn_kind(cfg.n_dense_prologue + j)
        ks, sub = jax.random.split(ks)
        keys = jax.random.split(sub, cfg.n_periods)
        period_params[f"pos{j}"] = jax.vmap(
            lambda k: blocks.init_layer(k, cfg, kind, ffn)
        )(keys)
    params["periods"] = period_params
    return params


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    cache: dict = {}
    if cfg.n_dense_prologue:
        cache["prologue"] = [
            blocks.init_layer_cache(cfg, cfg.pattern[0], batch, max_seq)
            for _ in range(cfg.n_dense_prologue)
        ]
    periods = {}
    for j, kind in enumerate(cfg.pattern):
        one = blocks.init_layer_cache(cfg, kind, batch, max_seq)
        periods[f"pos{j}"] = (
            None if one is None
            else jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)).copy(), one)
        )
    cache["periods"] = periods
    return cache


def _map_layer_caches(cfg: ModelConfig, fn, *caches):
    """Apply ``fn(kind, *layer_caches)`` over every layer cache of the trees.

    Prologue caches have their natural (B, ...) layout; scanned-period caches
    carry a leading ``n_periods`` axis, handled by vmapping ``fn`` over it.
    Walks the same structure ``init_decode_cache`` builds.
    """
    out: dict = {}
    if "prologue" in caches[0]:
        kind = cfg.pattern[0]
        out["prologue"] = [
            fn(kind, *(c["prologue"][i] for c in caches))
            for i in range(cfg.n_dense_prologue)
        ]
    periods = {}
    for j, kind in enumerate(cfg.pattern):
        layer = tuple(c["periods"][f"pos{j}"] for c in caches)
        periods[f"pos{j}"] = (
            None if layer[0] is None
            else jax.vmap(functools.partial(fn, kind))(*layer))
    out["periods"] = periods
    return out


def cache_slot_insert(cfg: ModelConfig, pool: dict, src: dict,
                      slots: jnp.ndarray) -> dict:
    """Insert the batch rows of a freshly prefilled cache into pool ``slots``.

    ``src`` comes from ``init_decode_cache(cfg, G, max_seq)`` + a bulk
    prefill of G admitted prompts (same ``max_seq`` as the pool); row i goes
    into pool slot ``slots[i]``.  Rows of other slots are untouched
    (bitwise), which is what makes mid-decode admission safe.
    """
    return _map_layer_caches(
        cfg, lambda kind, c, s: blocks.slot_insert_cache(kind, c, s, slots),
        pool, src)


def cache_expand_rows(cfg: ModelConfig, cache: dict, inv: jnp.ndarray) -> dict:
    """Gather batch rows ``inv`` of every layer cache — (G_unique, …) →
    (G, …).  Used by the admission dedupe: a group's unique prompts prefill
    once and the filled rows are expanded back to one per request.  Goes
    through ``_map_layer_caches`` because the batch axis sits behind the
    scanned ``n_periods`` axis on period leaves."""
    return _map_layer_caches(
        cfg,
        lambda kind, c: (None if c is None
                         else jax.tree.map(lambda x: x[inv], c)),
        cache)


def cache_slot_reset(cfg: ModelConfig, pool: dict, slots: jnp.ndarray) -> dict:
    """Zero pool ``slots`` — bitwise identical to freshly initialized rows."""
    return _map_layer_caches(
        cfg, lambda kind, c: blocks.slot_reset_cache(kind, c, slots), pool)


# --------------------------------------------------------------------------
# paged decode cache (DESIGN.md §13)
#
# The paged pool is a *split* pair of trees with the same layer structure as
# ``init_decode_cache``:
#
# * ``pages``  — (num_pages, page_size, …) arenas for layers whose cache has
#   a sequence axis (attention/MLA); None at recurrent/cacheless positions.
# * ``state``  — plain (n_slots, …) rows for recurrent layers (mamba/RWKV);
#   None at paged/cacheless positions.
#
# A decode tick gathers per-slot views from ``pages`` through the page
# table, merges in ``state`` (pure host-side structure surgery — no copies),
# runs the SAME compiled decode step as the contiguous engine on the merged
# tree, then commits the written position back to ``pages`` and re-extracts
# ``state``.  The split exists because decode donates its cache argument:
# recurrent leaves passed through a gather jit unchanged would alias the
# pool's buffers, and donation would free them under it.
# --------------------------------------------------------------------------

_RECURRENT_KINDS = ("mamba", "rwkv")


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int) -> dict:
    """Page-arena tree: one (num_pages, page_size, …) arena per paged layer
    (scanned periods carry the usual leading ``n_periods`` axis); a single
    page id addresses the same physical page in every arena."""
    cache: dict = {}
    if cfg.n_dense_prologue:
        cache["prologue"] = [
            blocks.init_paged_layer_cache(cfg, cfg.pattern[0], num_pages,
                                          page_size)
            for _ in range(cfg.n_dense_prologue)
        ]
    periods = {}
    for j, kind in enumerate(cfg.pattern):
        one = blocks.init_paged_layer_cache(cfg, kind, num_pages, page_size)
        periods[f"pos{j}"] = (
            None if one is None
            else jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)).copy(), one)
        )
    cache["periods"] = periods
    return cache


def init_paged_state(cfg: ModelConfig, n_slots: int) -> dict:
    """Recurrent-state tree: (n_slots, …) rows for mamba/RWKV layers only."""
    cache: dict = {}
    if cfg.n_dense_prologue:
        cache["prologue"] = [
            blocks.init_paged_state_cache(cfg, cfg.pattern[0], n_slots)
            for _ in range(cfg.n_dense_prologue)
        ]
    periods = {}
    for j, kind in enumerate(cfg.pattern):
        one = blocks.init_paged_state_cache(cfg, kind, n_slots)
        periods[f"pos{j}"] = (
            None if one is None
            else jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_periods, *x.shape)).copy(), one)
        )
    cache["periods"] = periods
    return cache


def paged_gather_cache(cfg: ModelConfig, pages: dict, pt: jnp.ndarray,
                       max_seq: int) -> dict:
    """Gather per-slot contiguous views from every page arena (unmapped
    table entries read the reserved zero page → fresh-cache bytes)."""
    return _map_layer_caches(
        cfg,
        lambda kind, c: blocks.paged_view_cache(cfg, kind, c, pt, max_seq),
        pages)


def paged_commit_cache(cfg: ModelConfig, pages: dict, view: dict,
                       pt: jnp.ndarray, pos: jnp.ndarray,
                       max_seq: int) -> dict:
    """Scatter the position each active slot just wrote in ``view`` back
    into the arenas (ring-adjusted per layer family)."""
    return _map_layer_caches(
        cfg,
        lambda kind, c, v: blocks.paged_commit_cache(cfg, kind, c, v, pt,
                                                     pos, max_seq),
        pages, view)


def paged_insert_cache(cfg: ModelConfig, pages: dict, src: dict,
                       pt_rows: jnp.ndarray) -> dict:
    """Scatter freshly prefilled cache rows into newly mapped pages
    (``src`` is the same tree ``cache_slot_insert`` takes)."""
    return _map_layer_caches(
        cfg,
        lambda kind, c, s: blocks.paged_insert_cache(kind, c, s, pt_rows),
        pages, src)


def paged_copy_pages(cfg: ModelConfig, pages: dict, src_ids: jnp.ndarray,
                     dst_ids: jnp.ndarray) -> dict:
    """Copy whole pages across every arena (COW fork).  Padding the id
    vectors with (0, 0) makes the batch shape static — copying the zero
    page onto itself is a no-op."""
    return _map_layer_caches(
        cfg,
        lambda kind, c: blocks.paged_copy_pages(kind, c, src_ids, dst_ids),
        pages)


def merge_paged_view(cfg: ModelConfig, view: dict, state: dict) -> dict:
    """Splice gathered paged views and recurrent state rows into one full
    cache tree (host-side structure surgery — the merged tree references
    the same buffers, byte-equal to the contiguous engine's pool)."""
    out: dict = {}
    if "prologue" in view:
        out["prologue"] = [
            v if v is not None else s
            for v, s in zip(view["prologue"], state["prologue"])
        ]
    out["periods"] = {
        key: (v if v is not None else state["periods"][key])
        for key, v in view["periods"].items()
    }
    return out


def extract_paged_state(cfg: ModelConfig, cache: dict) -> dict:
    """Select the recurrent-state half of a full cache tree (pure structural
    selection — no copies; the leaves stay the decode step's outputs)."""
    out: dict = {}
    if "prologue" in cache:
        keep = cfg.pattern[0] in _RECURRENT_KINDS
        out["prologue"] = [c if keep else None for c in cache["prologue"]]
    out["periods"] = {
        f"pos{j}": (cache["periods"][f"pos{j}"]
                    if kind in _RECURRENT_KINDS else None)
        for j, kind in enumerate(cfg.pattern)
    }
    return out


def extract_state_rows(cfg: ModelConfig, cache: dict, row: int) -> dict:
    """Slice one batch row of the recurrent leaves of a freshly prefilled
    cache — the constant-size state a prefix-cache entry stores."""
    state = extract_paged_state(cfg, cache)
    out: dict = {}
    if "prologue" in state:
        out["prologue"] = [
            None if c is None else jax.tree.map(lambda x: x[row:row + 1], c)
            for c in state["prologue"]
        ]
    out["periods"] = {
        key: (None if c is None
              else jax.tree.map(lambda x: x[:, row:row + 1], c))
        for key, c in state["periods"].items()
    }
    return out


def mask_cache_update(cfg: ModelConfig, old: dict, new: dict,
                      active: jnp.ndarray) -> dict:
    """Keep ``new`` cache rows where ``active`` (B,) bool, else ``old``.

    Free/padded slots of a continuous-batching decode step keep their cache
    bitwise unchanged — a parked SWA ring doesn't advance, a parked SSM/WKV
    state doesn't decay.
    """
    def merge(kind, o, n):
        sel = lambda a, b: jnp.where(
            active.reshape((-1,) + (1,) * (a.ndim - 1)), b, a)
        return jax.tree.map(sel, o, n)

    return _map_layer_caches(cfg, merge, old, new)


def cache_snapshot(cfg: ModelConfig, cache: dict) -> dict:
    """The per-step rollback state speculative decode must keep (§11).

    Returns a tree of the same layer structure as ``cache`` where every leaf
    that cannot be rewound by position alone (recurrent mamba/rwkv state,
    rolling SWA rings — ``blocks.cache_needs_snapshot``) is the layer's
    current cache, and every positionally-rewindable layer is an empty
    ``()`` placeholder.  Stacked over the draft scan, these snapshots let
    ``cache_rollback`` commit the exact post-step-``m`` state.
    """
    def pick(kind, c):
        return c if blocks.cache_needs_snapshot(cfg, kind, c) else ()

    return _map_layer_caches(cfg, pick, cache)


def cache_rollback(cfg: ModelConfig, cache: dict, snap: dict) -> dict:
    """Commit a speculative block: merge a selected step's snapshot leaves
    back over the draft-final ``cache``.

    Snapshot-kind layers take the snapshot (the bitwise state after the
    accepted step); positional layers keep the draft-final buffers — their
    stale entries beyond the rewound position counter are masked by the
    ``k_pos < cache_pos + 1`` decode check and overwritten before they can
    ever be attended (models/attention.py, models/mla.py).
    """
    def merge(kind, c, s):
        return s if blocks.cache_needs_snapshot(cfg, kind, c) else c

    return _map_layer_caches(cfg, merge, cache, snap)


def dense_verify_logits(params: dict, hidden: jnp.ndarray,
                        cfg: ModelConfig) -> jnp.ndarray:
    """``forward()``'s dense unembed tail on externally-carried hiddens.

    ``hidden`` is the f32 output of ``return_hidden=True`` — it round-trips
    exactly to the bf16 final-norm activations it came from (bf16→f32 is
    injective), so casting back to the table dtype reproduces the very
    einsum ``forward`` would have run.  A 2-D (B, d) input is lifted to the
    (B, 1, d) decode shape before the contraction: XLA's 2-D matmul is *not*
    bitwise-identical to the 3-D einsum rows, and bitwise parity with the
    in-forward path is the whole point (tests/test_spec_decode.py).  A 3-D
    (K, B, d) block — the stacked hiddens of a speculative draft scan — maps
    row-for-row to the per-step logits.
    """
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    squeeze = hidden.ndim == 2
    if squeeze:
        hidden = hidden[:, None, :]
    logits = unembed(hidden.astype(table.dtype), table).astype(jnp.float32)
    logits = constrain(logits, "dp", None, "tp")  # vocab-parallel logits
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits[:, 0] if squeeze else logits


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _period_body(cfg: ModelConfig, x, positions, period_params, period_cache,
                 encoder_states, cache_pos):
    """Apply one period (all pattern positions). Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for j, kind in enumerate(cfg.pattern):
        ffn = cfg.ffn_kind(cfg.n_dense_prologue + j)
        layer_cache = None if period_cache is None else period_cache.get(f"pos{j}")
        x, nc, a = blocks.apply_layer(
            period_params[f"pos{j}"], x, positions, cfg, kind, ffn,
            encoder_states=encoder_states, cache=layer_cache,
            cache_pos=cache_pos)
        new_cache[f"pos{j}"] = nc
        aux = aux + a
    return x, new_cache, aux


def forward(
    params: dict,
    tokens: jnp.ndarray,                     # (B, S) int32
    cfg: ModelConfig,
    *,
    encoder_states: Optional[jnp.ndarray] = None,   # (B, T_enc, d) stub frontend
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    remat: bool = True,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Run the backbone. Returns (logits, new_cache, aux_loss).

    ``return_hidden=True`` stops after the final norm and returns the
    (B, S, d_model) f32 hidden states in place of logits — the input the
    Representer-Sketch head consumes instead of the dense unembed
    (repro.core.sketch_lm_head / repro.kernels.fused_decode).
    """
    b, s = tokens.shape
    x = embed(tokens, params["embed"]) * jnp.asarray(
        cfg.d_model ** 0.5, jnp.bfloat16)
    x = constrain(x, "dp", None, None)
    if cache_pos is None:
        positions = jnp.arange(s)
        cache_pos_v = jnp.zeros((), jnp.int32)
    elif jnp.ndim(cache_pos) == 1:
        # Per-slot position counters (continuous-batching decode): every
        # sequence is at its own depth, so RoPE angles and attention masks
        # become (B, S)-shaped.
        positions = cache_pos[:, None] + jnp.arange(s)[None, :]
        cache_pos_v = cache_pos
    else:
        positions = cache_pos + jnp.arange(s)
        cache_pos_v = cache_pos

    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    # Prologue (unscanned).
    if "prologue" in params:
        pcaches = (cache or {}).get("prologue", [None] * cfg.n_dense_prologue)
        new_p = []
        for i, lp in enumerate(params["prologue"]):
            x, nc, a = blocks.apply_layer(
                lp, x, positions, cfg, cfg.pattern[0], "dense",
                encoder_states=encoder_states, cache=pcaches[i],
                cache_pos=cache_pos_v)
            new_p.append(nc)
            aux = aux + a
        if cache is not None:
            new_cache["prologue"] = new_p

    # Scanned periods.
    period_cache = (cache or {}).get("periods")

    def body(carry, scanned):
        xc, auxc = carry
        pp, pc = scanned
        xc, nc, a = _period_body(cfg, xc, positions, pp, pc,
                                 encoder_states, cache_pos_v)
        return (xc, auxc + a), nc

    if remat and cache is None:
        body = jax.checkpoint(body)

    (x, aux), scanned_cache = jax.lax.scan(
        body, (x, aux), (params["periods"], period_cache))
    if cache is not None:
        new_cache["periods"] = scanned_cache

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return (x.astype(jnp.float32),
                (new_cache if cache is not None else None), aux)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(x, table).astype(jnp.float32)
    logits = constrain(logits, "dp", None, "tp")  # vocab-parallel logits
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits, (new_cache if cache is not None else None), aux


# --------------------------------------------------------------------------
# losses / steps
# --------------------------------------------------------------------------

def lm_loss(
    params: dict,
    tokens: jnp.ndarray,       # (B, S)
    labels: jnp.ndarray,       # (B, S) — next-token targets, -1 = masked
    cfg: ModelConfig,
    *,
    encoder_states: Optional[jnp.ndarray] = None,
    aux_coef: float = 0.01,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, _, aux = forward(params, tokens, cfg, encoder_states=encoder_states)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    # Vocab-parallel cross-entropy: never gather the (B, S, V) logits.
    # logsumexp reduces over the sharded vocab axis (small all-reduce of
    # (B, S) stats); the label logit is picked with an iota==label mask that
    # the SPMD partitioner keeps sharded — no 26 GB take_along_axis gather.
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    iota_v = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(iota_v == safe[..., None], logits, 0.0), axis=-1)
    nll = lse - label_logit
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    loss = ce + aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


def decode_step(
    params: dict,
    cache: dict,
    tokens: jnp.ndarray,       # (B, 1) — the newest token
    pos: jnp.ndarray,          # int32 tokens-already-cached: scalar, or (B,)
                               # per-slot counters (continuous batching)
    cfg: ModelConfig,
    *,
    encoder_states: Optional[jnp.ndarray] = None,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    """One decode step: returns (logits (B, V), updated cache).

    ``return_hidden=True`` returns the (B, d_model) final hidden instead of
    logits — the dense unembed is skipped entirely so a sketched head can
    replace it (the paper's serving hot path).
    """
    out, new_cache, _ = forward(
        params, tokens, cfg, encoder_states=encoder_states,
        cache=cache, cache_pos=pos, remat=False,
        return_hidden=return_hidden)
    return out[:, -1], new_cache
